"""Elastic execution layer (PR 5 acceptance surface): heterogeneous
NodeSpec nodes, engine add/retire/preempt events, the ClusterSim elastic
policy, mutable worker pools, and coordinator-based worker discovery."""
import argparse
import dataclasses
import os
import socket
import subprocess
import sys
import threading
import time

import pytest

from repro.api import (Experiment, InprocWorker, WorkerLostError, WorkerPool,
                       make_scheduler)
from repro.cluster.engine import ClusterConfig, EventEngine, NodeSpec
from repro.cluster.executor import ClusterTrialExecutor
from repro.cluster.sim import (SIM_SYS_DEFAULT, ClusterSim, ElasticPolicy,
                               SimBackend, make_arrivals)
from repro.core import TuneV1
from repro.core.job import HPTJob, Param, SearchSpace
from repro.core.worker import (TrialCompletion, Worker, WorkerCapabilities)
from repro.service import (CoordinatorClient, CoordinatorService,
                           ElasticWorkerPoolExecutor, RemoteWorker,
                           WorkerAnnouncer, serve_coordinator)
from repro.service.transport import _recv_msg, _send_msg


def _space():
    return SearchSpace([
        Param("batch_size", "choice", choices=(32, 64, 256, 1024)),
        Param("learning_rate", "log", 0.001, 0.1),
    ])


def _job(seed=0, epochs=9):
    return HPTJob(workload="lenet-mnist", space=_space(), max_epochs=epochs,
                  seed=seed)


# ----------------------------------------------------- engine: NodeSpec

def test_nodespec_speed_scales_epoch_durations():
    eng = EventEngine(ClusterConfig(nodes=[NodeSpec(speed=2.0),
                                           NodeSpec(speed=0.5)]))
    fast = eng.submit("fast", iter([10.0]))
    slow = eng.submit("slow", iter([10.0]))
    eng.run()
    assert fast.service_s == 5.0                # 10s of work at 2x
    assert slow.service_s == 20.0               # 10s of work at 0.5x


def test_nodespec_capacity_multiplexes_one_node():
    eng = EventEngine(ClusterConfig(nodes=[NodeSpec(capacity=2)]))
    a = eng.submit("a", iter([30.0]))
    b = eng.submit("b", iter([30.0]))
    c = eng.submit("c", iter([30.0]))
    eng.run()
    assert a.start_s == b.start_s == 0.0        # both slots used at once
    assert a.node == b.node == 0
    assert c.start_s == 30.0                    # queued for a slot


def test_cluster_config_back_compat_and_nodespec_authority():
    legacy = ClusterConfig(n_nodes=3, node_tags=("a", "a", "b"))
    assert [s.tag for s in legacy.nodes] == ["a", "a", "b"]
    assert all(s.speed == 1.0 and s.capacity == 1 for s in legacy.nodes)
    hetero = ClusterConfig(nodes=[NodeSpec(speed=2.0), NodeSpec()])
    assert hetero.n_nodes == 2                  # derived from the specs
    with pytest.raises(ValueError, match="NodeSpec"):
        ClusterConfig(nodes=[NodeSpec()], node_tags=("a",))
    with pytest.raises(ValueError):
        NodeSpec(speed=0.0)
    with pytest.raises(ValueError):
        NodeSpec(capacity=0)


# --------------------------------------- engine: add / retire / preempt

def test_add_node_picks_up_waiting_task():
    eng = EventEngine(ClusterConfig(n_nodes=1, seed=0))
    eng.submit("x", iter([30.0]))
    y = eng.submit("y", iter([30.0]))
    eng.add_node(NodeSpec(), at=5.0)
    eng.run()
    assert y.start_s == 5.0 and y.node == 1     # joined node took the waiter


def test_retire_node_drains_at_epoch_boundary_with_reshard_charge():
    cfg = ClusterConfig(n_nodes=2, seed=0)
    eng = EventEngine(cfg)
    t = eng.submit("t", iter([10.0] * 4))
    eng.retire_node(0, at=15.0)                 # mid-epoch 2
    eng.run()
    # epoch 2 finishes on node 0 at t=20, then the task migrates to node 1
    # and pays restore+reconfig on its next epoch
    charge = cfg.restore_s + cfg.reconfig_s
    assert t.n_preemptions == 1
    assert t.node == 1                          # finished on the survivor
    assert t.service_s == 40.0 + charge
    assert t.finish_s == 40.0 + charge
    assert t.n_epochs == 4                      # nothing lost, nothing redone


def test_preempt_requeues_behind_waiter_without_losing_epochs():
    cfg = ClusterConfig(n_nodes=1, seed=0)
    eng = EventEngine(cfg)
    yielded = []

    def gen():
        for _ in range(3):
            yielded.append(1)
            yield 10.0

    t1 = eng.submit("t1", gen())
    t2 = eng.submit("t2", iter([5.0]))
    eng.preempt("t1", at=12.0)
    eng.run()
    charge = cfg.restore_s + cfg.reconfig_s
    assert t1.n_preemptions == 1
    assert t2.start_s == 20.0                   # the waiter got the slot
    assert t1.n_epochs == 3 and len(yielded) == 3   # exactly one pull/epoch
    # epochs 1-2 ran before the boundary; epoch 3 resumes at t2's finish
    # (25) and pays the reshard charge
    assert t1.finish_s == 25.0 + 10.0 + charge
    # preempting a finished or waiting task is a no-op
    eng2 = EventEngine(cfg)
    s = eng2.submit("s", iter([1.0]))
    eng2.run()
    eng2.preempt("s")
    assert s.n_preemptions == 0


def test_retire_at_final_epoch_boundary_finishes_in_place():
    """A task whose generator is exhausted at the boundary has nothing to
    migrate: it finishes on the draining node — no spurious preemption, no
    'unplaceable' error even when no other node exists."""
    eng = EventEngine(ClusterConfig(nodes=[NodeSpec()], seed=0))
    t = eng.submit("t", iter([10.0]))
    eng.retire_node(0, at=5.0)
    eng.run()
    assert t.finish_s == 10.0 and t.n_preemptions == 0
    eng2 = EventEngine(ClusterConfig(n_nodes=2, seed=0))
    t2 = eng2.submit("t", iter([10.0]))
    eng2.retire_node(0, at=5.0)
    eng2.run()
    assert t2.n_preemptions == 0                # survivor node not involved


def test_retiring_the_only_compatible_node_is_a_loud_error():
    eng = EventEngine(ClusterConfig(n_nodes=1, seed=0))
    eng.submit("a", iter([10.0, 10.0]))
    eng.submit("b", iter([10.0]))               # waits behind a
    eng.retire_node(0, at=5.0)
    with pytest.raises(RuntimeError, match="unplaceable"):
        eng.run()


def test_elastic_event_schedule_is_bit_deterministic():
    """Acceptance: identical seeds + identical join/retire/preempt schedules
    -> bit-identical stats (times and counters), with faults on."""
    def run_once():
        eng = EventEngine(ClusterConfig(n_nodes=2, straggler_prob=0.3,
                                        mtbf_s=500.0, seed=11))
        stats = [eng.submit(f"t{i}", iter([50.0] * 5)) for i in range(5)]
        eng.add_node(NodeSpec(speed=0.5), at=60.0)
        eng.retire_node(0, at=120.0)
        eng.preempt("t1", at=80.0)
        eng.add_node(NodeSpec(speed=2.0), at=200.0)
        eng.run()
        return [dataclasses.asdict(s) for s in stats]

    r1, r2 = run_once(), run_once()
    assert r1 == r2
    assert sum(s["n_preemptions"] for s in r1) > 0


# ---------------------------------------------------- sim: ElasticPolicy

def _bursty_jobs(n=10, mean=30.0, seed=0):
    return make_arrivals(["lenet-mnist", "cnn-news20"], n_jobs=n,
                         mean_interarrival_s=mean, space=_space(),
                         max_epochs=4, seed=seed)


def _run_sim(elastic, jobs, seed=0):
    sim = ClusterSim(ClusterConfig(n_nodes=2, seed=seed),
                     lambda: TuneV1(SimBackend()), elastic=elastic)
    return sim.run(jobs, scheduler="random", n_trials=2)


def test_elastic_policy_splits_merges_and_beats_static():
    jobs = _bursty_jobs()
    static = _run_sim(None, jobs)
    policy = ElasticPolicy(split_queue=2)
    elastic = _run_sim(policy, jobs)
    assert policy.n_splits > 0 and policy.n_merges > 0
    assert sum(o.n_preemptions for o in elastic) > 0    # a real re-shard
    mean = lambda out: sum(o.response_s for o in out) / len(out)  # noqa: E731
    assert mean(elastic) < mean(static)
    # elasticity perturbs *time* only: accuracies are untouched
    assert [o.best_accuracy for o in elastic] == \
        [o.best_accuracy for o in static]


def test_elastic_sim_runs_are_bit_identical():
    """Acceptance: two elastic runs with identical seeds and schedules are
    bit-identical in scores and sim times."""
    jobs = _bursty_jobs()
    a = _run_sim(ElasticPolicy(split_queue=2), jobs)
    b = _run_sim(ElasticPolicy(split_queue=2), jobs)
    assert [dataclasses.asdict(o) for o in a] == \
        [dataclasses.asdict(o) for o in b]


def test_elastic_policy_requires_event_mode_and_validates():
    with pytest.raises(ValueError, match="event"):
        ClusterSim(ClusterConfig(), lambda: None, mode="legacy",
                   elastic=ElasticPolicy())
    with pytest.raises(ValueError):
        ElasticPolicy(split_factor=1)
    with pytest.raises(ValueError):
        ElasticPolicy(split_speed=1.5)


# ------------------------------------------- executor: preemption parity

def test_executor_preemption_changes_time_never_scores():
    """A retire+rejoin schedule on the trial executor migrates running
    trials (paying the reshard charge) but every epoch's accuracy is
    bit-identical to serial — a preempted trial never loses or repeats a
    completed epoch."""
    serial = (Experiment(_job()).with_tuner("v1").with_backend("sim")
              .with_scheduler("hyperband").run())

    ex = ClusterTrialExecutor(cluster=ClusterConfig(n_nodes=2, seed=0),
                              default_sys=SIM_SYS_DEFAULT)
    # t=350 lands mid-way through a 3-epoch rung resume on node 0 (1-epoch
    # dispatches are exhausted at their boundary and finish in place, so a
    # retire during the first rung would migrate nothing)
    ex.retire_node(0, at=350.0)
    ex.add_node(NodeSpec(), at=700.0)
    elastic = (Experiment(_job()).with_tuner("v1").with_backend("sim")
               .with_scheduler("hyperband").run(executor=ex))
    migrated = [s for s in ex.engine.completed if s.n_preemptions > 0]
    assert migrated, "schedule never caused a migration"
    assert sorted(serial.records) == sorted(elastic.records)
    for tid in serial.records:
        assert [e.accuracy for e in serial.records[tid].epochs] == \
            [e.accuracy for e in elastic.records[tid].epochs], tid
    assert serial.best_score == elastic.best_score
    baseline_ex = ClusterTrialExecutor(
        cluster=ClusterConfig(n_nodes=2, seed=0),
        default_sys=SIM_SYS_DEFAULT)
    baseline = (Experiment(_job()).with_tuner("v1").with_backend("sim")
                .with_scheduler("hyperband").run(executor=baseline_ex))
    assert elastic.sim_time_s > baseline.sim_time_s  # the charge is real


# ------------------------------------------------- pool: mutable membership

class _ScriptedWorker(Worker):
    """Deterministic fake: completions are released only when the test says
    so (None score = compute from trial id)."""

    kind = "scripted"

    def __init__(self, name, speed=1.0, capacity=1, fail_with=None):
        super().__init__()
        self.name = name
        self.speed = speed
        self.capacity = capacity
        self.fail_with = fail_with
        self.submitted = []
        self._pending = []

    def capabilities(self):
        return WorkerCapabilities(kind=self.kind, capacity=self.capacity,
                                  speed_factor=self.speed)

    @property
    def outstanding(self):
        return len(self._pending)

    def submit(self, trial, epochs=None):
        self.submitted.append(trial.trial_id)
        self._pending.append(trial)

    def poll(self, timeout=0.0):
        if not self._pending:
            return []
        if self.fail_with is not None:
            trial = self._pending.pop(0)
            return [TrialCompletion(trial.trial_id, float("nan"),
                                    error=self.fail_with)]
        if timeout <= 0:
            return []                           # only blocking polls finish
        trial = self._pending.pop(0)
        return [TrialCompletion(trial.trial_id, 1.0)]


class _P:
    def __init__(self, tid, clone_from=None, epochs=1):
        self.trial_id, self.clone_from = tid, clone_from
        self.hparams, self.epochs = {}, epochs


def test_weighted_placement_prefers_fast_and_wide_workers():
    slow = _ScriptedWorker("slow", speed=1.0)
    fast = _ScriptedWorker("fast", speed=3.0)
    pool = WorkerPool([slow, fast], sticky=True)
    for i in range(4):
        pool.place(_P(f"t{i}"))
    held = {}
    for w in pool._bindings.values():
        held[w.name] = held.get(w.name, 0) + 1
    assert held == {"fast": 3, "slow": 1}       # 3x speed -> 3x the trials
    wide = _ScriptedWorker("wide", capacity=4)
    narrow = _ScriptedWorker("narrow", capacity=1)
    free = WorkerPool([narrow, wide], sticky=False)
    wide._pending = [1, 2]                      # 2 in flight over 4 lanes
    narrow._pending = [1]                       # 1 in flight over 1 lane
    assert free.place(_P("x")) is wide          # 0.5 load beats 1.0


def test_poll_rotation_drains_a_worker_behind_a_straggler():
    """Satellite: a straggling first worker must not starve completions
    sitting in other workers' queues (the old loop hot-span busy[0])."""
    class _Straggler(_ScriptedWorker):
        def poll(self, timeout=0.0):
            return []                           # never completes anything

    straggler = _Straggler("s0")
    healthy = _ScriptedWorker("s1")
    pool = WorkerPool([straggler, healthy], sticky=True)
    runner = TuneV1(SimBackend())
    pool.bind(runner, "lenet-mnist")            # before pinning: a re-bind
    pool._bindings["a"] = straggler             # would clear the bindings
    pool._bindings["b"] = healthy
    done = {}
    t = threading.Thread(
        target=lambda: done.update(
            {"n": len(pool.run_wave(runner, "lenet-mnist", [_P("b")]))}),
        daemon=True)
    straggler.submit(_P("a"))                   # busy forever
    t.start()
    t.join(timeout=5.0)
    assert not t.is_alive(), "completion starved behind straggling worker"
    assert done["n"] == 1


def test_pool_add_and_remove_worker_mid_drive():
    w0 = _ScriptedWorker("w0")
    pool = WorkerPool([w0], sticky=True)
    runner = TuneV1(SimBackend())
    pool.bind(runner, "lenet-mnist")
    w1 = _ScriptedWorker("w1")
    pool.add_worker(w1)
    assert w1.runner is runner                  # bound on join
    for i in range(4):
        pool._dispatch(_P(f"t{i}"), 1)
    assert len(w0.submitted) == len(w1.submitted) == 2
    # removing w1 re-places its in-flight trials onto w0
    pool.remove_worker(w1)
    assert pool.workers == [w0]
    assert sorted(w0.submitted) == ["t0", "t1", "t2", "t3"]
    assert not pool._bindings or \
        all(w is w0 for w in pool._bindings.values())


def test_maintenance_runs_while_workers_are_busy():
    """A hung-but-connected worker never errors its transport; the only
    rescue is the maintenance hook (roster sync) retiring it — so the hook
    must run even while the pool blocks on busy workers."""
    class _Hung(_ScriptedWorker):
        def poll(self, timeout=0.0):
            return []                           # connected, never completes

    hung = _Hung("hung")
    healthy = _ScriptedWorker("healthy")
    pool = WorkerPool([hung, healthy], sticky=True)
    runner = TuneV1(SimBackend())
    pool.bind(runner, "lenet-mnist")
    pool._bindings["a"] = hung                  # pin "a" onto the hung worker

    calls = []

    def evict_hung():
        calls.append(1)
        if len(calls) > 1 and hung in pool.workers:
            pool.remove_worker(hung)            # the roster pruned it

    # first call happens at wave start (before dispatch) — the eviction
    # must come from the *blocked* poll loop, after "a" is in flight
    pool.maintenance = evict_hung
    out = pool.run_wave(runner, "lenet-mnist", [_P("a")])
    assert [(p.trial_id, s) for p, s in out] == [("a", 1.0)]
    assert pool.workers == [healthy]            # re-placed and completed


def test_pool_retires_lost_worker_and_replaces_its_trials():
    lost = RuntimeError("boom")
    lost.worker_lost = True
    dying = _ScriptedWorker("dying", fail_with=lost)
    healthy = _ScriptedWorker("healthy")
    pool = WorkerPool([dying, healthy], sticky=True)
    pool.retire_on_error = True
    runner = TuneV1(SimBackend())
    proposals = [_P(f"t{i}") for i in range(4)]
    out = pool.run_wave(runner, "lenet-mnist", proposals)
    assert [p.trial_id for p, _ in out] == ["t0", "t1", "t2", "t3"]
    assert pool.workers == [healthy]            # the dead worker is gone
    assert sorted(healthy.submitted) == ["t0", "t1", "t2", "t3"]
    # without the flag the error surfaces (a static pool stays honest)
    dying2 = _ScriptedWorker("dying2", fail_with=lost)
    strict = WorkerPool([dying2], sticky=True)
    with pytest.raises(RuntimeError, match="boom"):
        strict.run_wave(runner, "lenet-mnist", [_P("x")])


# ------------------------------------------------ coordinator: the roster

def test_coordinator_register_heartbeat_expire_leave():
    clock = [0.0]
    svc = CoordinatorService(ttl_s=10.0, clock=lambda: clock[0])

    def call(op, **kw):
        resp = svc.handle({"op": op, **kw})
        assert resp.get("ok"), resp
        return resp

    a = call("register", address="tcp://10.0.0.1:7078")["worker_id"]
    b = call("register", address="tcp://10.0.0.2:7078",
             speed_factor=2.0)["worker_id"]
    roster = call("roster")
    assert [w["address"] for w in roster["workers"]] == \
        ["tcp://10.0.0.1:7078", "tcp://10.0.0.2:7078"]
    assert roster["workers"][1]["speed_factor"] == 2.0
    v0 = roster["version"]
    # b heartbeats, a goes silent past the ttl -> pruned, version bumps
    clock[0] = 8.0
    call("heartbeat", worker_id=b)
    clock[0] = 12.0
    roster = call("roster")
    assert [w["worker_id"] for w in roster["workers"]] == [b]
    assert roster["version"] > v0
    # a's next heartbeat is rejected -> its announcer re-registers,
    # replacing any stale same-address entry
    assert not svc.handle({"op": "heartbeat", "worker_id": a})["ok"]
    call("register", address="tcp://10.0.0.1:7078")
    call("register", address="tcp://10.0.0.1:7078")
    assert len(call("roster")["workers"]) == 2  # no ghost duplicate
    call("leave", worker_id=b)
    assert [w["address"] for w in call("roster")["workers"]] == \
        ["tcp://10.0.0.1:7078"]


def test_worker_announcer_registers_and_leaves():
    server = serve_coordinator(CoordinatorService(ttl_s=5.0), port=0,
                               background=True)
    try:
        coord = f"tcp://127.0.0.1:{server.server_address[1]}"
        ann = WorkerAnnouncer(coord, "tcp://127.0.0.1:9999",
                              speed_factor=1.5)
        ann.start()
        client = CoordinatorClient(coord)
        roster = client.roster()
        assert [w["address"] for w in roster] == ["tcp://127.0.0.1:9999"]
        assert roster[0]["speed_factor"] == 1.5
        ann.stop()
        assert client.roster() == []            # graceful leave, not ttl
        client.close()
    finally:
        server.shutdown()


# ----------------------------------- satellite: transport death is named

def test_remote_worker_transport_death_names_the_address():
    """A socket failure mid-run must say *which* worker died, not surface a
    raw OSError; the error carries the worker_lost flag pools retire on."""
    probe = socket.socket()
    probe.bind(("127.0.0.1", 0))
    probe.listen(1)
    port = probe.getsockname()[1]

    def one_hello_then_die():
        conn, _ = probe.accept()
        req = _recv_msg(conn)
        if req.get("op") == "_wire":        # decline like a JSON-only peer
            _send_msg(conn, {"ok": False, "error": "unsupported"})
            req = _recv_msg(conn)
        _send_msg(conn, {"ok": True, "kind": "remote", "capacity": 1})
        conn.close()

    threading.Thread(target=one_hello_then_die, daemon=True).start()
    worker = RemoteWorker(f"tcp://127.0.0.1:{port}", runner_spec={})
    with pytest.raises(WorkerLostError,
                       match=f"tcp://127.0.0.1:{port}.*'run'"):
        worker._request({"op": "run", "workload": "w", "trial_id": "t",
                         "hparams": {}, "epochs": 1})
    probe.close()
    # an unreachable worker at construction is named the same way
    with pytest.raises(WorkerLostError, match=f"tcp://127.0.0.1:{port}"):
        RemoteWorker(f"tcp://127.0.0.1:{port}", runner_spec={},
                     connect_timeout=0.2, connect_retries=0)


# ---------------------------------------- acceptance: live demo, end to end

class _GatedScheduler:
    """Wrap a scheduler so the test controls when wave N+1 is released —
    the deterministic way to land a worker join 'mid-run'."""

    def __init__(self, inner, gate_after_wave=1):
        self.inner = inner
        self.gate = threading.Event()
        self._waves = 0
        self._gate_after = gate_after_wave

    def suggest(self):
        wave = self.inner.suggest()
        if wave:
            if self._waves == self._gate_after:
                assert self.gate.wait(timeout=60.0), "test gate timed out"
            self._waves += 1
        return wave

    def report(self, trial_id, score):
        self.inner.report(trial_id, score)

    def best(self):
        return self.inner.best()

    @property
    def done(self):
        return self.inner.done


def _spawn(args, expect, timeout=30.0):
    """Start `python -m <args>` from the repo root; wait for a line
    containing `expect` and return (proc, line)."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    proc = subprocess.Popen(
        [sys.executable, "-m", *args],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
        env={**os.environ, "PYTHONPATH": "src"}, cwd=root)
    deadline = time.time() + timeout
    while time.time() < deadline:
        line = proc.stdout.readline()
        if expect in line:
            return proc, line
    proc.terminate()
    raise AssertionError(f"{args}: never printed {expect!r}")


def _addr_of(line):
    return "tcp://" + line.split(" on ", 1)[1].split()[0]


@pytest.mark.slow
def test_worker_joining_mid_run_receives_trials_live():
    """Acceptance: start a coordinator, start an experiment with
    --coordinator, launch a second `python -m repro.worker --announce`
    mid-run, and observe the pool dispatching trials to it — real
    subprocesses on ephemeral ports."""
    procs = []
    try:
        coord_proc, line = _spawn(
            ["repro.coordinator", "--port", "0", "--ttl", "10"],
            "coordinator on")
        procs.append(coord_proc)
        coord = _addr_of(line)

        w1, _ = _spawn(["repro.worker", "--port", "0", "--announce", coord],
                       "announced to")
        procs.append(w1)

        from repro.launch.sysargs import add_executor_args, \
            executor_from_args
        args = add_executor_args(argparse.ArgumentParser()).parse_args(
            ["--coordinator", coord])
        ex = executor_from_args(args)
        assert isinstance(ex, ElasticWorkerPoolExecutor)

        job = _job()
        sched = _GatedScheduler(make_scheduler("hyperband", job))
        holder = {}

        def run():
            holder["res"] = (Experiment(job).with_tuner("v1")
                             .with_backend("sim").with_scheduler(sched)
                             .run(executor=ex))

        t = threading.Thread(target=run)
        t.start()
        # second worker announces mid-run, before the gate releases wave 2
        w2, _ = _spawn(["repro.worker", "--port", "0", "--announce", coord],
                       "announced to")
        procs.append(w2)
        client = CoordinatorClient(coord)
        deadline = time.time() + 30.0
        while len(client.roster()) < 2 and time.time() < deadline:
            time.sleep(0.1)
        assert len(client.roster()) == 2
        client.close()
        sched.gate.set()
        t.join(timeout=120.0)
        assert not t.is_alive(), "experiment hung"

        assert len(ex.workers) == 2             # the join was picked up
        dispatched = list(ex.pool.dispatched.values())
        assert len(dispatched) == 2 and all(n > 0 for n in dispatched), \
            f"pool never dispatched to the joined worker: {dispatched}"
        serial = (Experiment(_job()).with_tuner("v1").with_backend("sim")
                  .with_scheduler("hyperband").run())
        assert holder["res"].best_score == serial.best_score
        assert sorted(holder["res"].records) == sorted(serial.records)
    finally:
        if "ex" in dir():
            ex.close()
        for p in procs:
            p.terminate()
        for p in procs:
            p.wait(timeout=10)


@pytest.mark.slow
def test_killed_worker_is_retired_and_its_trials_finish_elsewhere():
    """A worker that dies mid-job (SIGKILL, no goodbye) is retired and its
    trials re-placed, with serial-identical scores. The orchestration that
    used to live inline here is now the declarative `sigkill_worker` chaos
    scenario (repro.obs.scenarios) — this asserts its SLO report."""
    from repro.obs.chaos import run_scenario
    from repro.obs.scenarios import SCENARIOS

    report = run_scenario(SCENARIOS["sigkill_worker"])
    assert report.passed, report.summary()
    assert report.recovery_s is not None
    assert report.recovery_s <= SCENARIOS["sigkill_worker"].retire_budget_s()
    assert report.replaced >= 1                 # trials really moved


# ----------------------------------------------- launch-flag integration

def test_sysargs_coordinator_flag():
    from repro.launch.sysargs import add_executor_args, executor_from_args

    def parse(argv):
        return add_executor_args(argparse.ArgumentParser()).parse_args(argv)

    with pytest.raises(ValueError, match="--coordinator.*cluster"):
        executor_from_args(parse(["--coordinator", "tcp://h:1",
                                  "--executor", "cluster"]))
    with pytest.raises(ValueError, match="--executor workers needs"):
        executor_from_args(parse(["--executor", "workers"]))
    server = serve_coordinator(CoordinatorService(), port=0, background=True)
    try:
        coord = f"tcp://127.0.0.1:{server.server_address[1]}"
        ex = executor_from_args(parse(["--coordinator", coord]))
        assert isinstance(ex, ElasticWorkerPoolExecutor)
        assert ex.workers == []                 # roster-only pool
        # --workers entries ride along as static members
        ex2 = executor_from_args(parse(["--coordinator", coord,
                                        "--workers", "sim"]))
        assert len(ex2.workers) == 1
        assert isinstance(ex2.workers[0], InprocWorker)
        ex.close()
        ex2.close()
    finally:
        server.shutdown()


def test_elastic_executor_requires_a_runner_spec():
    server = serve_coordinator(CoordinatorService(), port=0, background=True)
    try:
        coord = f"tcp://127.0.0.1:{server.server_address[1]}"
        ex = ElasticWorkerPoolExecutor(coord)
        with pytest.raises(ValueError, match="runner_spec"):
            ex.configure_runner_spec(None)      # underivable spec: loud, not
        ex.close()                              # silently-wrong remote runs
        explicit = ElasticWorkerPoolExecutor(coord, runner_spec={})
        explicit.configure_runner_spec(None)    # {} opts into CLI defaults
        assert explicit._runner_spec == {}
        explicit.close()
    finally:
        server.shutdown()
