"""Observability + chaos subsystem (PR 6 acceptance surface): the typed
event layer and its bus, sinks (JSONL trace / MetricsStore / memory), the
scrapeable metrics endpoint, emitters across the pool / engine /
coordinator / store service, WorkerLostError enrichment, coordinator TTL
edge cases, the idempotent MetricsStore flush, the ``--trace`` launch flag,
and SLO evaluation over synthetic event streams."""
import argparse
import json
import math
import os
import socket
import threading
import time
import types

import pytest

from repro.obs import (EVENT_TYPES, EpochCompleted, Event, EventBus,
                       HeartbeatMissed, Resharded, StoreRefit,
                       TrialCompleted, TrialDispatched, WorkerJoined,
                       WorkerRetired, event_from_dict, get_bus, set_bus,
                       worker_label)
from repro.obs.sinks import (JsonlSink, MemorySink, MetricsStoreSink,
                             attach_trace, read_trace)


# --------------------------------------------------------- the event bus

def test_bus_is_inert_until_observed():
    bus = EventBus()
    assert not bus.enabled
    bus.emit(TrialDispatched(trial_id="t", worker="w"))
    assert bus.seq == 0 and bus.counters == {}      # emit was a no-op
    mem = MemorySink()
    bus.add_sink(mem)                               # subscribing enables
    assert bus.enabled
    bus.emit(TrialDispatched(trial_id="t", worker="w"))
    assert len(mem.records) == 1
    assert EventBus().enable().enabled              # explicit observer


def test_bus_stamps_ts_seq_and_counts():
    bus = EventBus()
    mem = MemorySink()
    bus.add_sink(mem)
    t0 = time.time()
    bus.emit(TrialDispatched(trial_id="a", worker="w", epochs=3))
    bus.emit(TrialCompleted(trial_id="a", worker="w", score=0.5))
    bus.emit(TrialDispatched(trial_id="b", worker="w"))
    a, done, b = mem.records
    assert a["seq"] == 1 and done["seq"] == 2 and b["seq"] == 3
    assert a["ts"] >= t0 and a["kind"] == "trial_dispatched"
    assert a["epochs"] == 3
    assert bus.counters == {"trial_dispatched": 2, "trial_completed": 1}
    # an explicit ts (the engine's simulated clock) is honored verbatim
    bus.emit(EpochCompleted(trial_id="a", worker="w", at_s=12.5), ts=99.0)
    assert mem.records[-1]["ts"] == 99.0 and mem.records[-1]["at_s"] == 12.5


def test_event_roundtrip_and_unknown_kind():
    bus = EventBus()
    mem = MemorySink()
    bus.add_sink(mem)
    bus.emit(WorkerRetired(worker="tcp://h:1", reason="worker_lost",
                           inflight=2))
    ts, seq, ev = event_from_dict(mem.records[0])
    assert isinstance(ev, WorkerRetired) and seq == 1 and ts > 0
    assert ev.reason == "worker_lost" and ev.inflight == 2
    with pytest.raises(ValueError, match="unknown event kind"):
        event_from_dict({"kind": "from_the_future"})
    assert set(EVENT_TYPES) == {
        "trial_dispatched", "trial_started", "trial_completed",
        "epoch_completed", "worker_joined", "worker_retired",
        "heartbeat_missed", "resharded", "store_refit", "rpc_completed",
        "clock_sync", "forward_dropped"}
    assert all(issubclass(c, Event) for c in EVENT_TYPES.values())


def test_bus_ring_tail_and_failing_sink_is_dropped():
    bus = EventBus(capacity=4)
    bad_calls = []

    def bad_sink(rec):
        bad_calls.append(rec)
        raise RuntimeError("boom")

    mem = MemorySink()
    bus.add_sink(bad_sink)
    bus.add_sink(mem)
    for i in range(6):
        bus.emit(TrialDispatched(trial_id=f"t{i}", worker="w"))
    # one failure evicts the sink; the healthy one saw everything
    assert len(bad_calls) == 1 and len(mem.records) == 6
    # the ring holds the last `capacity` records; cursors advance past them
    assert [r["seq"] for r in bus.events_since(0)] == [3, 4, 5, 6]
    assert [r["seq"] for r in bus.events_since(5)] == [6]
    assert bus.events("trial_dispatched")[-1]["trial_id"] == "t5"


def test_default_bus_swap_is_scoped():
    fresh = EventBus()
    prev = set_bus(fresh)
    try:
        assert get_bus() is fresh
    finally:
        set_bus(prev)
    assert get_bus() is prev


def test_worker_label_precedence():
    assert worker_label(types.SimpleNamespace(address=("10.0.0.1", 7078))) \
        == "tcp://10.0.0.1:7078"
    assert worker_label(types.SimpleNamespace(address=None, tag="sim#1",
                                              name="x")) == "sim#1"
    assert worker_label(types.SimpleNamespace(name="w2")) == "w2"
    anon = types.SimpleNamespace(kind="inproc")
    assert worker_label(anon).startswith("inproc:")


# ---------------------------------------------------------------- sinks

def test_jsonl_sink_roundtrip_and_torn_tail(tmp_path):
    path = str(tmp_path / "trace.jsonl")
    bus = EventBus()
    sink = attach_trace(bus, path)
    bus.emit(TrialDispatched(trial_id="a", worker="w"))
    bus.emit(StoreRefit(version=3, n_entries=7))
    sink.close()
    recs = read_trace(path)
    assert [r["kind"] for r in recs] == ["trial_dispatched", "store_refit"]
    assert read_trace(path, kind="store_refit")[0]["n_entries"] == 7
    # a torn final line (crash mid-append) is dropped silently
    with open(path, "a") as f:
        f.write('{"kind": "trial_co')
    assert len(read_trace(path)) == 2
    # an earlier malformed line is corruption and raises
    with open(path, "w") as f:
        f.write('not json\n{"kind": "store_refit", "version": 1}\n')
    with pytest.raises(ValueError):
        read_trace(path)


def test_metrics_store_sink_bridges_events(tmp_path):
    from repro.core.store import MetricsStore
    bus = EventBus()
    with MetricsStore(str(tmp_path / "ms")) as store:
        bus.add_sink(MetricsStoreSink(store))
        bus.emit(WorkerRetired(worker="tcp://h:1", reason="heartbeat"))
        bus.emit(TrialDispatched(trial_id="t0", worker="tcp://h:2"))
        store.flush()
        rows = store.query("events", tags={"kind": "worker_retired"})
        assert len(rows) == 1
        assert rows[0]["tags"]["worker"] == "tcp://h:1"
        assert rows[0]["fields"]["reason"] == "heartbeat"
        assert store.query("events", tags={"trial_id": "t0"})


# -------------------------------------------------- the metrics endpoint

def test_render_metrics_and_obs_endpoint():
    from repro.obs.metrics import ObsClient, render_metrics, serve_obs
    bus = EventBus().enable()
    bus.emit(WorkerJoined(worker="a"))
    bus.emit(WorkerJoined(worker="b"))
    bus.emit(WorkerRetired(worker="a", reason="leave"))
    bus.emit(TrialDispatched(trial_id="t", worker="b"))
    bus.emit(HeartbeatMissed(worker="a", age_s=3.0, ttl_s=2.0))
    text = render_metrics(bus)
    assert "repro_events_total 5" in text
    assert 'repro_events{kind="worker_joined"} 2' in text
    assert "repro_workers_live 1" in text          # 2 joined - 1 retired
    assert "repro_trials_inflight 1" in text
    assert "repro_heartbeats_missed 1" in text
    server = serve_obs(bus, port=0, background=True)
    try:
        client = ObsClient(f"tcp://127.0.0.1:{server.server_address[1]}")
        assert client.metrics() == text
        assert client.counters()["trial_dispatched"] == 1
        events = client.tail()
        assert [e["kind"] for e in events][:2] == ["worker_joined"] * 2
        assert client.cursor == 5
        assert client.tail() == []                  # cursor advanced
        bus.emit(TrialCompleted(trial_id="t", worker="b", score=1.0))
        assert [e["kind"] for e in client.tail()] == ["trial_completed"]
        client.close()
    finally:
        server.shutdown()


def test_obs_cli_chaos_list_and_unknown(capsys):
    from repro.obs.__main__ import main
    assert main(["chaos", "--list"]) == 0
    out = capsys.readouterr().out
    assert "sigkill_worker" in out and "slow_node" in out
    assert main(["chaos", "no_such_scenario"]) == 2


# ------------------------------------------------------- pool emissions

class _FakeWorker:
    """Minimal scriptable Worker for pool-emission tests."""

    kind = "fake"

    def __init__(self, name, fail_with=None):
        from repro.obs.events import get_bus
        self.name = name
        self.fail_with = fail_with
        self.runner, self.workload = None, None
        self.bus = get_bus()
        self._pending = []

    def capabilities(self):
        from repro.core.worker import WorkerCapabilities
        return WorkerCapabilities(kind=self.kind, capacity=2,
                                  speed_factor=1.5)

    @property
    def outstanding(self):
        return len(self._pending)

    def bind(self, runner, workload):
        self.runner, self.workload = runner, workload

    def submit(self, trial, epochs=None):
        self._pending.append(trial)

    def poll(self, timeout=0.0):
        from repro.core.worker import TrialCompletion
        if not self._pending:
            return []
        if self.fail_with is not None:
            trial = self._pending.pop(0)
            return [TrialCompletion(trial.trial_id, float("nan"),
                                    error=self.fail_with)]
        if timeout <= 0:
            return []
        trial = self._pending.pop(0)
        return [TrialCompletion(trial.trial_id, 1.0)]

    def clone(self, dst, src):
        pass

    def close(self):
        pass


class _P:
    def __init__(self, tid, epochs=1):
        self.trial_id, self.clone_from = tid, None
        self.hparams, self.epochs = {}, epochs


def test_pool_emits_join_dispatch_complete():
    from repro.cluster.sim import SimBackend
    from repro.core import TuneV1
    from repro.core.worker import WorkerPool
    bus = EventBus()
    mem = MemorySink()
    bus.add_sink(mem)
    pool = WorkerPool([], allow_empty=True, sticky=True)
    pool.bus = bus
    w = _FakeWorker("w0")
    pool.add_worker(w)
    assert w.bus is bus                             # propagated on join
    joined = mem.of_kind("worker_joined")
    assert len(joined) == 1
    assert joined[0]["worker"] == "w0"              # worker_label: .name
    assert joined[0]["worker_kind"] == "fake"
    assert joined[0]["capacity"] == 2
    assert joined[0]["speed_factor"] == 1.5
    out = pool.run_wave(TuneV1(SimBackend()), "lenet-mnist",
                        [_P("t0", epochs=2)])
    assert len(out) == 1
    d = mem.of_kind("trial_dispatched")
    assert [(r["trial_id"], r["worker"], r["epochs"]) for r in d] == \
        [("t0", "w0", 2)]
    c = mem.of_kind("trial_completed")
    assert [(r["trial_id"], r["score"], r["error"]) for r in c] == \
        [("t0", 1.0, None)]


def test_pool_emits_retire_and_reshard():
    from repro.core.worker import WorkerPool
    bus = EventBus()
    mem = MemorySink()
    bus.add_sink(mem)
    a, b = _FakeWorker("a"), _FakeWorker("b")
    pool = WorkerPool([a, b], sticky=True)
    pool.bus = bus
    pool._dispatch(_P("t0"), 1)
    pool._dispatch(_P("t1"), 1)
    victim = pool._inflight_worker["t0"]
    survivor = b if victim is a else a
    pool.remove_worker(victim, reason="worker_lost")
    retired = mem.of_kind("worker_retired")
    assert len(retired) == 1
    assert retired[0]["worker"] == victim.name
    assert retired[0]["reason"] == "worker_lost"
    assert retired[0]["inflight"] == 1              # t0 was in flight on it
    moved = mem.of_kind("resharded")
    assert [(r["trial_id"], r["src"], r["dst"]) for r in moved] == \
        [("t0", victim.name, survivor.name)]
    # failed-but-not-lost completions carry the error string
    bad = RuntimeError("exploded")
    failer = _FakeWorker("f", fail_with=bad)
    pool2 = WorkerPool([failer], sticky=True)
    pool2.bus = bus
    pool2._dispatch(_P("tx"), 1)
    with pytest.raises(RuntimeError, match="exploded"):
        pool2._poll_once(block=True)
    errs = [r for r in mem.of_kind("trial_completed") if r["error"]]
    assert errs and errs[-1]["trial_id"] == "tx"
    assert "exploded" in errs[-1]["error"]


def test_executor_attach_bus_propagates():
    from repro.cluster.executor import ClusterTrialExecutor
    from repro.core.worker import WorkerPoolExecutor
    bus = EventBus()
    ex = WorkerPoolExecutor([_FakeWorker("w")])
    ex.attach_bus(bus)
    assert ex.pool.bus is bus and ex.workers[0].bus is bus
    ex2 = ClusterTrialExecutor(n_nodes=2)
    ex2.attach_bus(bus)
    assert ex2.pool.bus is bus and ex2.worker.bus is bus
    assert ex2.engine.bus is bus
    ex2.close()


# ----------------------------------------------------- engine emissions

def test_engine_emits_sim_time_events():
    from repro.cluster.engine import ClusterConfig, EventEngine, NodeSpec
    bus = EventBus()
    mem = MemorySink()
    bus.add_sink(mem)
    eng = EventEngine(ClusterConfig(n_nodes=1, seed=0))
    eng.bus = bus
    t = eng.submit("t", iter([10.0] * 3))
    eng.add_node(NodeSpec(speed=2.0, capacity=2), at=5.0)
    eng.retire_node(0, at=15.0)                     # mid-epoch 2: reshard
    eng.run()
    assert t.n_preemptions == 1
    joined = mem.of_kind("worker_joined")
    assert [(r["worker"], r["worker_kind"], r["at_s"]) for r in joined] == \
        [("node:1", "sim", 5.0)]
    assert joined[0]["speed_factor"] == 2.0 and joined[0]["capacity"] == 2
    retired = mem.of_kind("worker_retired")
    assert [(r["worker"], r["reason"], r["at_s"]) for r in retired] == \
        [("node:0", "retired", 15.0)]
    assert retired[0]["inflight"] == 1              # t was running on it
    moved = mem.of_kind("resharded")
    assert [(r["trial_id"], r["src"], r["at_s"]) for r in moved] == \
        [("t", "node:0", 20.0)]                     # the epoch-2 boundary
    # dispatches and epochs carry simulated time, not wall clock
    d = mem.of_kind("trial_dispatched")
    assert [(r["worker"], r["at_s"]) for r in d] == \
        [("node:0", 0.0), ("node:1", 20.0)]
    epochs = mem.of_kind("epoch_completed")
    assert len(epochs) == t.n_epochs == 3
    assert all(r["worker"].startswith("node:") for r in epochs)
    assert epochs[0]["at_s"] == 10.0                # sim completion times
    assert [r["epoch"] for r in epochs] == [0, 1, 2]


# ------------------------------------- coordinator: events + TTL edges

def _coord(ttl=10.0):
    from repro.service import CoordinatorService
    clock = [0.0]
    svc = CoordinatorService(ttl_s=ttl, clock=lambda: clock[0])
    bus = EventBus()
    mem = MemorySink()
    bus.add_sink(mem)
    svc.bus = bus

    def call(op, **kw):
        resp = svc.handle({"op": op, **kw})
        assert resp.get("ok"), resp
        return resp

    return svc, clock, mem, call


def test_coordinator_emits_join_leave_and_heartbeat_events():
    svc, clock, mem, call = _coord(ttl=10.0)
    a = call("register", address="tcp://10.0.0.1:7078",
             speed_factor=2.0, capacity=3)["worker_id"]
    joined = mem.of_kind("worker_joined")
    assert joined[0]["worker"] == "tcp://10.0.0.1:7078"
    assert joined[0]["worker_kind"] == "roster"
    assert joined[0]["capacity"] == 3 and joined[0]["speed_factor"] == 2.0
    call("leave", worker_id=a)
    retired = mem.of_kind("worker_retired")
    assert [(r["worker"], r["reason"]) for r in retired] == \
        [("tcp://10.0.0.1:7078", "leave")]
    # leaving twice emits nothing more (the entry is already gone)
    call("leave", worker_id=a)
    assert len(mem.of_kind("worker_retired")) == 1
    # silence past the TTL: HeartbeatMissed names the killing age
    call("register", address="tcp://10.0.0.2:7078")
    clock[0] = 11.0
    call("version")
    missed = mem.of_kind("heartbeat_missed")
    assert len(missed) == 1
    assert missed[0]["worker"] == "tcp://10.0.0.2:7078"
    assert missed[0]["age_s"] == 11.0 and missed[0]["ttl_s"] == 10.0
    pruned = mem.of_kind("worker_retired")[-1]
    assert pruned["worker"] == "tcp://10.0.0.2:7078"
    assert pruned["reason"] == "heartbeat"


def test_heartbeat_exactly_at_ttl_survives():
    """The prune cutoff is strict (<): a worker whose last heartbeat is
    exactly ttl_s old is still on the roster — at-the-boundary workers are
    kept, not flapped."""
    svc, clock, mem, call = _coord(ttl=10.0)
    a = call("register", address="tcp://10.0.0.1:7078")["worker_id"]
    clock[0] = 10.0                                 # age == ttl exactly
    roster = call("roster")
    assert [w["worker_id"] for w in roster["workers"]] == [a]
    assert call("heartbeat", worker_id=a)           # still known
    assert mem.of_kind("heartbeat_missed") == []
    clock[0] = 20.0 + 1e-9                          # now strictly past it
    assert call("roster")["workers"] == []
    assert len(mem.of_kind("heartbeat_missed")) == 1


def test_reregistration_of_pruned_worker_same_address():
    """A pruned worker that comes back (same address) re-registers cleanly:
    new worker id, exactly one roster slot, no ghost duplicate."""
    svc, clock, mem, call = _coord(ttl=10.0)
    a = call("register", address="tcp://10.0.0.1:7078")["worker_id"]
    clock[0] = 11.0
    assert call("roster")["workers"] == []          # pruned
    assert not svc.handle({"op": "heartbeat", "worker_id": a})["ok"]
    b = call("register", address="tcp://10.0.0.1:7078")["worker_id"]
    assert b != a                                   # a fresh identity
    roster = call("roster")["workers"]
    assert [w["worker_id"] for w in roster] == [b]
    assert [w["address"] for w in roster] == ["tcp://10.0.0.1:7078"]
    # the old id stays dead even though the address is live again
    assert not svc.handle({"op": "heartbeat", "worker_id": a})["ok"]
    assert call("heartbeat", worker_id=b)
    assert len(mem.of_kind("worker_joined")) == 2


def test_roster_version_monotonic_across_prune_and_rejoin():
    svc, clock, mem, call = _coord(ttl=10.0)
    versions = [call("version")["version"]]

    def bump(op, **kw):
        call(op, **kw)
        versions.append(call("version")["version"])

    bump("register", address="tcp://10.0.0.1:7078")
    bump("register", address="tcp://10.0.0.2:7078")
    clock[0] = 11.0                                 # both prune
    versions.append(call("version")["version"])
    bump("register", address="tcp://10.0.0.1:7078")  # rejoin
    clock[0] = 22.0                                 # prune again
    versions.append(call("version")["version"])
    assert versions == sorted(versions)             # never regresses
    assert len(set(versions)) == len(versions)      # every change bumps


# ----------------------------------------------- store service emission

def test_store_service_emits_refit_events():
    from repro.service import GroundTruthService
    svc = GroundTruthService()
    bus = EventBus()
    mem = MemorySink()
    bus.add_sink(mem)
    svc.bus = bus
    add = {"op": "add", "profile": [1.0, 2.0], "workload": "w",
           "sys_config": {"k": 1}, "objective": 0.5}
    assert svc.handle(add)["ok"]
    refits = mem.of_kind("store_refit")
    assert len(refits) == 1 and refits[0]["n_entries"] == 1
    assert svc.handle({**add, "profile": [3.0, 4.0], "refit": False})["ok"]
    assert len(mem.of_kind("store_refit")) == 1     # deferred: no event
    assert svc.handle({"op": "refit"})["ok"]
    refits = mem.of_kind("store_refit")
    assert len(refits) == 2
    assert refits[1]["n_entries"] == 2
    assert refits[1]["version"] > refits[0]["version"]


# ------------------------------- satellite: WorkerLostError enrichment

def test_worker_lost_error_carries_heartbeat_age_and_last_trial():
    from repro.service import RemoteWorker, WorkerLostError
    from repro.service.transport import _recv_msg, _send_msg
    probe = socket.socket()
    probe.bind(("127.0.0.1", 0))
    probe.listen(1)
    port = probe.getsockname()[1]

    def one_hello_then_die():
        conn, _ = probe.accept()
        req = _recv_msg(conn)
        if req.get("op") == "_wire":        # decline like a JSON-only peer
            _send_msg(conn, {"ok": False, "error": "unsupported"})
            req = _recv_msg(conn)
        _send_msg(conn, {"ok": True, "kind": "remote", "capacity": 1})
        conn.close()

    threading.Thread(target=one_hello_then_die, daemon=True).start()
    worker = RemoteWorker(f"tcp://127.0.0.1:{port}", runner_spec={})
    # the hello succeeded, so the client has last-contact history; give it
    # completed-trial history the way _loop would after an install
    worker._last_trial, worker._last_epochs = "t7", 3
    with pytest.raises(WorkerLostError) as ei:
        worker._request({"op": "run", "workload": "w", "trial_id": "t",
                         "hparams": {}, "epochs": 1})
    err = ei.value
    assert err.age_s is not None and err.age_s >= 0.0
    assert err.last_trial == "t7" and err.last_epochs == 3
    msg = str(err)
    assert f"tcp://127.0.0.1:{port}" in msg
    assert "last ok" in msg and "last completed trial t7 @3 epochs" in msg
    probe.close()
    # with no successful request ever, the enrichment is absent, not fake
    with pytest.raises(WorkerLostError) as ei2:
        RemoteWorker(f"tcp://127.0.0.1:{port}", runner_spec={},
                     connect_timeout=0.2, connect_retries=0)
    assert ei2.value.age_s is None
    assert ei2.value.last_trial is None and ei2.value.last_epochs is None
    assert "last ok" not in str(ei2.value)


# ------------------------------ satellite: idempotent MetricsStore flush

def _rows(path):
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


def test_metrics_store_flush_is_idempotent(tmp_path):
    from repro.core.store import MetricsStore, _flush_buffers
    root = str(tmp_path / "ms")
    ms = MetricsStore(root)
    ms.write("m", {"v": 1})
    # overlapping triggers: explicit close, a second close, and the
    # GC/atexit finalizer path — one row, no matter how many fire
    ms.flush()
    ms.close()
    ms.close()
    _flush_buffers(ms.root, ms._buffers, ms._lock)
    assert len(_rows(os.path.join(root, "m.jsonl"))) == 1


def test_metrics_store_partial_write_failure_never_duplicates(tmp_path):
    """Regression: a flush that dies mid-batch (record 2 unserializable)
    must not leave record 1 in the buffer — the old write-then-clear order
    re-wrote already-written rows on the next flush trigger."""
    from repro.core.store import MetricsStore
    root = str(tmp_path / "ms")
    ms = MetricsStore(root)
    ms.write("m", {"v": 1})
    ms.write("m", {"v": object()})                  # json.dumps will raise
    with pytest.raises(TypeError):
        ms.flush()
    ms.close()                                      # close re-triggers flush
    rows = _rows(os.path.join(root, "m.jsonl"))
    assert [r["fields"] for r in rows] == [{"v": 1}]    # once, not twice


# ---------------------------------------- satellite: the --trace flag

def _parse(argv):
    from repro.launch.sysargs import add_executor_args
    return add_executor_args(argparse.ArgumentParser()).parse_args(argv)


def test_trace_flag_rejected_without_a_bus_capable_executor():
    from repro.launch.sysargs import executor_from_args
    with pytest.raises(ValueError, match="--trace.*serial"):
        executor_from_args(_parse(["--trace", "/tmp/t.jsonl"]))
    with pytest.raises(ValueError, match="--trace.*parallel"):
        executor_from_args(_parse(["--executor", "parallel",
                                   "--trace", "/tmp/t.jsonl"]))


def test_trace_flag_writes_events_on_a_cluster_run(tmp_path):
    from repro.api import Experiment
    from repro.core.job import HPTJob, Param, SearchSpace
    from repro.launch.sysargs import executor_from_args
    path = str(tmp_path / "run.jsonl")
    ex = executor_from_args(_parse(["--executor", "cluster", "--trace",
                                    path]))
    space = SearchSpace([
        Param("batch_size", "choice", choices=(32, 64)),
        Param("learning_rate", "log", 0.001, 0.1),
    ])
    job = HPTJob(workload="lenet-mnist", space=space, max_epochs=3, seed=0)
    (Experiment(job).with_tuner("v1").with_backend("sim")
     .with_scheduler("hyperband").run(executor=ex))
    recs = read_trace(path)
    kinds = {r["kind"] for r in recs}
    assert "trial_dispatched" in kinds and "trial_completed" in kinds
    assert "epoch_completed" in kinds               # engine sim-time events
    n = len(recs)
    ex.close()
    # a second traced run appends to the same file
    ex2 = executor_from_args(_parse(["--executor", "cluster", "--trace",
                                     path]))
    (Experiment(job).with_tuner("v1").with_backend("sim")
     .with_scheduler("hyperband").run(executor=ex2))
    assert len(read_trace(path)) > n
    ex2.close()


# ------------------------------------ SLO evaluation (synthetic streams)

def _fake_result(trials, best=0.9):
    rec = lambda accs: types.SimpleNamespace(     # noqa: E731
        epochs=[types.SimpleNamespace(accuracy=a) for a in accs])
    return types.SimpleNamespace(
        records={tid: rec(accs) for tid, accs in trials.items()},
        best_score=best)


def _mk_records(t_kill):
    mk = lambda kind, ts, **kw: {"kind": kind, "ts": ts, **kw}  # noqa: E731
    v, s = "tcp://victim:1", "tcp://survivor:2"
    return [
        mk("worker_joined", t_kill - 5.0, worker=v),
        mk("worker_joined", t_kill - 5.0, worker=s),
        mk("trial_dispatched", t_kill - 4.0, trial_id="t0", worker=v),
        mk("trial_dispatched", t_kill - 4.0, trial_id="t1", worker=s),
        mk("trial_completed", t_kill - 3.0, trial_id="t1", worker=s,
           error=None),
        mk("worker_retired", t_kill + 1.5, worker=v, reason="worker_lost"),
        mk("trial_dispatched", t_kill + 1.6, trial_id="t0", worker=s),
        mk("trial_completed", t_kill + 2.0, trial_id="t0", worker=s,
           error=None),
    ]


def test_slo_evaluation_passes_on_a_clean_recovery():
    from repro.obs.chaos import ChaosScenario, KillWorkers, _evaluate
    scn = ChaosScenario(name="synthetic", description="", ttl_s=2.0,
                        fault=KillWorkers(victims=1))
    t_kill = 1000.0
    trials = {"t0": [0.5, 0.6], "t1": [0.7]}
    report = _evaluate(scn, _mk_records(t_kill),
                       _fake_result(trials), _fake_result(trials),
                       t_kill, ["tcp://victim:1"], None, EventBus(), 3.0)
    assert report.passed, report.summary()
    by_name = {s.name: s for s in report.slos}
    assert by_name["time_to_retire_s"].value == 1.5
    assert report.recovery_s == 1.5
    assert by_name["trials_replaced"].value == report.replaced == 1
    assert by_name["no_lost_or_repeated_epochs"].ok
    assert by_name["bit_identical_scores"].ok


def test_slo_evaluation_flags_violations():
    from repro.obs.chaos import ChaosScenario, KillWorkers, _evaluate
    scn = ChaosScenario(name="synthetic", description="", ttl_s=2.0,
                        fault=KillWorkers(victims=1))
    t_kill = 1000.0
    trials = {"t0": [0.5, 0.6], "t1": [0.7]}
    v = "tcp://victim:1"
    # 1) the victim is never retired, and its trial never finishes
    records = [r for r in _mk_records(t_kill)
               if not (r["ts"] > t_kill or r["kind"] == "worker_retired")]
    divergent = _fake_result({"t0": [0.5, 0.99], "t1": [0.7]}, best=0.1)
    report = _evaluate(scn, records, divergent, _fake_result(trials),
                       t_kill, [v], None, EventBus(), 3.0)
    assert not report.passed
    by_name = {s.name: s for s in report.slos}
    assert not by_name["time_to_retire_s"].ok
    assert "never retired" in by_name["time_to_retire_s"].detail
    assert not by_name["trials_replaced"].ok
    assert not by_name["no_lost_or_repeated_epochs"].ok   # 0.99 != 0.6
    assert not by_name["bit_identical_scores"].ok         # 0.1 != 0.9
    # 2) a retirement past the budget fails the timing SLO alone
    late = _mk_records(t_kill)
    late[5] = {**late[5], "ts": t_kill + scn.retire_budget_s() + 1.0}
    report2 = _evaluate(scn, late, _fake_result(trials),
                        _fake_result(trials), t_kill, [v], None,
                        EventBus(), 3.0)
    assert not {s.name: s for s in report2.slos}["time_to_retire_s"].ok
    # 3) a heartbeat-missed floor bites when the partition never bit
    from repro.obs.chaos import PartitionCoordinator, SLOBudget
    pscn = ChaosScenario(
        name="part", description="", fault=PartitionCoordinator(),
        slo=SLOBudget(require_replacement=False, min_heartbeats_missed=1))
    report3 = _evaluate(pscn, _mk_records(t_kill), _fake_result(trials),
                        _fake_result(trials), None, [], None,
                        EventBus(), 3.0)
    assert not report3.passed
    assert not {s.name: s for s in report3.slos}["heartbeats_missed"].ok
    # 4) the slow-node dispatch-share cap
    sscn = ChaosScenario(
        name="slow", description="",
        slo=SLOBudget(require_replacement=False, max_dispatch_share=0.25))
    slow = "tcp://slow:3"
    records4 = _mk_records(t_kill) + [
        {"kind": "trial_dispatched", "ts": t_kill, "trial_id": f"s{i}",
         "worker": slow} for i in range(3)]
    report4 = _evaluate(sscn, records4, _fake_result(trials),
                        _fake_result(trials), None, [], slow,
                        EventBus(), 3.0)
    share = {s.name: s for s in report4.slos}["slow_node_dispatch_share"]
    assert not share.ok                             # 3 of 6 tcp dispatches


def test_scenario_pack_shape():
    from repro.obs.chaos import ChaosScenario
    from repro.obs.scenarios import SCENARIOS
    assert {"sigkill_worker", "sigkill_storm", "partition_coordinator",
            "partition_store", "slow_node"} <= set(SCENARIOS)
    for name, scn in SCENARIOS.items():
        assert isinstance(scn, ChaosScenario) and scn.name == name
        assert scn.description
        assert scn.retire_budget_s() > 0
    assert SCENARIOS["sigkill_worker"].n_workers == 2
    assert SCENARIOS["partition_store"].with_store


# ------------------------------------------ live chaos (slow, real procs)

@pytest.mark.slow
def test_chaos_partition_coordinator_scenario_live():
    """A refused coordinator mid-run: the pool keeps driving on the roster
    it has, heartbeats provably miss, and results stay serial-identical."""
    from repro.obs.chaos import run_scenario
    from repro.obs.scenarios import SCENARIOS

    report = run_scenario(SCENARIOS["partition_coordinator"])
    assert report.passed, report.summary()
    assert report.counters.get("heartbeat_missed", 0) >= 1


@pytest.mark.slow
def test_chaos_trace_artifact_is_readable(tmp_path):
    """The CI smoke invocation: run sigkill_worker with --trace and check
    the artifact decodes into typed events end to end."""
    from repro.obs.chaos import run_scenario
    from repro.obs.scenarios import SCENARIOS

    path = str(tmp_path / "chaos.jsonl")
    report = run_scenario(SCENARIOS["sigkill_worker"], trace_path=path)
    assert report.passed, report.summary()
    recs = read_trace(path)
    assert len(recs) == report.n_events
    typed = [event_from_dict(r)[2] for r in recs]
    kinds = {e.kind for e in typed}
    assert {"worker_joined", "trial_dispatched", "trial_completed",
            "worker_retired", "resharded", "epoch_completed"} <= kinds
    lost = [e for e in typed if isinstance(e, WorkerRetired)
            and e.reason in ("worker_lost", "roster")]
    assert lost and not math.isnan(report.wall_s)
