"""Cluster sim: FIFO, faults, stragglers, perf-model shape (paper Fig 3b)."""
import dataclasses

import numpy as np
import pytest

from repro.cluster import perfmodel
from repro.cluster.sim import (ClusterConfig, ClusterSim, SimBackend,
                               SimSystemSpace, make_arrivals)
from repro.core import GroundTruth, PipeTune, TuneV1
from repro.core.job import HPTJob, Param, SearchSpace
from repro.core.profiler import EpochProfile


def _space():
    return SearchSpace([
        Param("batch_size", "choice", choices=(32, 64, 256, 1024)),
        Param("learning_rate", "log", 0.001, 0.1),
    ])


def test_perfmodel_cores_vs_batch_tradeoff():
    """Paper Fig 3b: more chips help batch 1024, hurt batch 64."""
    fast_big = perfmodel.epoch_time_s("lenet-mnist", 1024, 16)
    slow_big = perfmodel.epoch_time_s("lenet-mnist", 1024, 4)
    assert fast_big < slow_big
    fast_small = perfmodel.epoch_time_s("lenet-mnist", 64, 4)
    slow_small = perfmodel.epoch_time_s("lenet-mnist", 64, 16)
    assert fast_small < slow_small


def test_perfmodel_memory_pressure():
    t_small = perfmodel.epoch_time_s("lenet-mnist", 1024, 8, memory_gb=32)
    t_paged = perfmodel.epoch_time_s("lenet-mnist", 1024, 8, memory_gb=1)
    assert t_paged > t_small


def test_accuracy_surface_tradeoffs():
    """Paper Fig 3a: larger batch -> worse accuracy (at same epochs)."""
    hp32 = {"batch_size": 32, "learning_rate": 0.01}
    hp1024 = {"batch_size": 1024, "learning_rate": 0.01}
    a32 = perfmodel.accuracy_at("lenet-mnist", hp32, 8)
    a1024 = perfmodel.accuracy_at("lenet-mnist", hp1024, 8)
    assert a32 > a1024


def test_profiles_cluster_by_family():
    """Paper Fig 8: same-family workloads cluster together."""
    from repro.core import KMeans
    vecs, labels = [], []
    for wl, fam in [("lenet-mnist", 0), ("lenet-fashion", 0),
                    ("cnn-news20", 1), ("lstm-news20", 1)]:
        for s in range(4):
            vecs.append(perfmodel.profile_vector(wl, 64, 8, seed=s))
            labels.append(fam)
    km = KMeans(k=2, seed=0).fit(np.stack(vecs))
    pred = [km.predict(v)[0] for v in vecs]
    # all type-I in one cluster, all type-II in the other
    t1 = {p for p, l in zip(pred, labels) if l == 0}
    t2 = {p for p, l in zip(pred, labels) if l == 1}
    assert len(t1) == 1 and len(t2) == 1 and t1 != t2


def _jobs(n=4, seed=0):
    return make_arrivals(["lenet-mnist", "cnn-news20"], n_jobs=n,
                         mean_interarrival_s=100.0, space=_space(),
                         max_epochs=6, seed=seed)


def test_fifo_response_ordering():
    sim = ClusterSim(ClusterConfig(n_nodes=1, seed=0),
                     lambda: TuneV1(SimBackend()))
    out = sim.run(_jobs(3), scheduler="random", n_trials=2)
    # single node: each job starts after the previous finishes
    for a, b in zip(out, out[1:]):
        assert b.start >= a.finish - 1e-6


def test_failures_add_service_time():
    base = ClusterSim(ClusterConfig(n_nodes=2, seed=3),
                      lambda: TuneV1(SimBackend()))
    faulty = ClusterSim(ClusterConfig(n_nodes=2, mtbf_s=500.0, seed=3),
                        lambda: TuneV1(SimBackend()))
    o1 = base.run(_jobs(3), scheduler="random", n_trials=2)
    o2 = faulty.run(_jobs(3), scheduler="random", n_trials=2)
    assert sum(o.n_failures for o in o2) > 0
    assert sum(o.service_s for o in o2) > sum(o.service_s for o in o1)


def test_straggler_mitigation_bounds_slowdown():
    slow = ClusterSim(ClusterConfig(n_nodes=2, straggler_prob=0.3,
                                    mitigate_stragglers=False, seed=5),
                      lambda: TuneV1(SimBackend()))
    mitigated = ClusterSim(ClusterConfig(n_nodes=2, straggler_prob=0.3,
                                         mitigate_stragglers=True, seed=5),
                           lambda: TuneV1(SimBackend()))
    t_slow = sum(o.service_s for o in slow.run(_jobs(3), scheduler="random",
                                               n_trials=2))
    t_mit = sum(o.service_s for o in mitigated.run(_jobs(3),
                                                   scheduler="random",
                                                   n_trials=2))
    assert t_mit < t_slow


@pytest.mark.parametrize("mode", ["event", "legacy"])
def test_fault_injection_is_deterministic_per_seed(mode):
    """Two runs with the same ClusterConfig.seed produce identical
    JobOutcome lists — service times, failure/straggler counts, the lot —
    on both the event engine and the legacy post-hoc path."""
    def run_once():
        sim = ClusterSim(ClusterConfig(n_nodes=2, seed=11, mtbf_s=800.0,
                                       straggler_prob=0.15),
                         lambda: TuneV1(SimBackend()), mode=mode)
        return sim.run(_jobs(4, seed=2), scheduler="random", n_trials=2)

    r1, r2 = run_once(), run_once()
    assert [dataclasses.asdict(o) for o in r1] == \
        [dataclasses.asdict(o) for o in r2]
    assert sum(o.n_failures + o.n_stragglers for o in r1) > 0


def test_event_and_legacy_modes_agree_on_scores():
    """Faults only ever perturb time: accuracies and epoch counts match
    between the event engine and the legacy path; timing may differ."""
    jobs = _jobs(3, seed=4)
    kw = dict(n_nodes=2, seed=5, mtbf_s=1000.0, straggler_prob=0.2)
    ev = ClusterSim(ClusterConfig(**kw), lambda: TuneV1(SimBackend()),
                    mode="event").run(jobs, scheduler="random", n_trials=2)
    lg = ClusterSim(ClusterConfig(**kw), lambda: TuneV1(SimBackend()),
                    mode="legacy").run(jobs, scheduler="random", n_trials=2)
    assert [o.best_accuracy for o in ev] == [o.best_accuracy for o in lg]
    assert [o.n_epochs for o in ev] == [o.n_epochs for o in lg]
    assert [o.job_id for o in ev] == [o.job_id for o in lg]


def test_sim_backend_profile_uses_raw_vector_mode():
    """The lambda monkey-patch is gone: SimBackend marks its profiles raw
    and ``vector()`` returns the modeled values verbatim."""
    be = SimBackend()
    ts = be.init_trial("lenet-mnist", {"batch_size": 64}, seed=0)
    _, res = be.run_epoch(ts, {})
    assert res.profile.raw
    assert "vector" not in vars(res.profile)        # no instance override
    expected = perfmodel.profile_vector("lenet-mnist", 64, 16, seed=0)
    np.testing.assert_array_equal(res.profile.vector(), expected)
    # round-trip construction
    v = np.array([1.5, -2.0, 3.25])
    np.testing.assert_array_equal(EpochProfile.from_vector(v).vector(), v)
    # non-raw profiles still log-compress
    assert EpochProfile({"hlo.flops": 1e12}).vector()[0] == \
        pytest.approx(np.log1p(1e12))


def test_pipetune_beats_v1_multi_tenant():
    jobs = _jobs(6, seed=1)
    v1 = ClusterSim(ClusterConfig(n_nodes=2, seed=0),
                    lambda: TuneV1(SimBackend()))
    r1 = v1.run(jobs, scheduler="random", n_trials=3)
    gt = GroundTruth()
    pt = ClusterSim(ClusterConfig(n_nodes=2, seed=0),
                    lambda: PipeTune(SimBackend(), SimSystemSpace(),
                                     groundtruth=gt, max_probes=4))
    rp = pt.run(jobs, scheduler="random", n_trials=3)
    resp1 = np.mean([o.response_s for o in r1])
    respp = np.mean([o.response_s for o in rp])
    acc1 = np.mean([o.best_accuracy for o in r1])
    accp = np.mean([o.best_accuracy for o in rp])
    assert respp < resp1
    assert accp > acc1 - 0.02
