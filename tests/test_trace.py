"""Distributed tracing + trace analysis (PR 8 acceptance surface): the
``obs_trace`` negotiation, the event-forwarding sink/collector pair, the
span/critical-path analyzer, the torn-trace and reconnecting-client
satellites, and the ``python -m repro.obs`` CLI."""
import argparse
import json
import socket
import subprocess
import sys
import threading
import time

import pytest

import repro.obs.__main__ as obs_cli
from repro.api import Experiment, RemoteWorker, WorkerPoolExecutor
from repro.core.job import HPTJob, Param, SearchSpace
from repro.obs.events import EventBus, new_trace_id
from repro.obs.forward import ForwardingSink, propagate_trace, \
    start_collector
from repro.obs.metrics import ObsClient, ObsUnreachable, serve_obs
from repro.obs.sinks import JsonlSink, MemorySink, read_trace
from repro.obs.trace import analyze_trace, build_trace, load_events, \
    render_report
from repro.service import (GroundTruthService, GroundTruthTCPServer,
                           JsonRPCServer, SocketTransport, StoreClient,
                           TrialWorkerService, serve_worker)


def _space():
    return SearchSpace([
        Param("batch_size", "choice", choices=(32, 64, 256, 1024)),
        Param("learning_rate", "log", 0.001, 0.1),
    ])


def _job(seed=0, epochs=9):
    return HPTJob(workload="lenet-mnist", space=_space(), max_epochs=epochs,
                  seed=seed)


def _free_port() -> int:
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


def _serve(handler):
    server = JsonRPCServer(("127.0.0.1", 0), handler)
    threading.Thread(target=server.serve_forever, daemon=True).start()
    return server


# ------------------------------------------------------- forwarding sink

def test_forwarding_sink_drops_oldest_when_bounded(monkeypatch):
    """The hot path never blocks: a full queue sheds the OLDEST record and
    counts it (the flusher is frozen here so the bound is what's tested)."""
    monkeypatch.setattr(ForwardingSink, "_run", lambda self: None)
    sink = ForwardingSink("tcp://127.0.0.1:9", maxlen=8, batch=64)
    for i in range(20):
        sink({"seq": i})
    assert sink.dropped_total == 12
    assert [r["seq"] for r in sink._queue] == list(range(12, 20))
    sink.close(timeout=0.2)             # dead collector: sheds, never hangs
    sink({"seq": 99})                   # post-close emit is a no-op
    assert len(sink._queue) == 8


def test_forwarding_sink_ships_batches_and_drop_receipts():
    home = EventBus()
    mem = MemorySink()
    home.add_sink(mem)
    collector = start_collector(home)
    sink = ForwardingSink(collector.address, proc="w", batch=4,
                          flush_interval_s=0.05)
    try:
        for i in range(6):
            sink({"kind": "epoch_completed", "seq": i, "ts": float(i),
                  "proc": "w", "trial_id": "t", "epoch": i})
        assert sink.flush(timeout=5.0)
        assert sink.dropped_total == 0
        got = mem.of_kind("epoch_completed")
        assert len(got) == 6
        # remote seq is preserved as rseq; a fresh local seq is stamped
        assert [r["rseq"] for r in got] == list(range(6))
        assert all(r["proc"] == "w" and r["seq"] > 0 for r in got)
        # a shed queue is reported as a forward_dropped receipt
        with sink._lock:
            sink._unreported_drops += 3
            sink._idle.clear()
        assert sink.flush(timeout=5.0)
        drops = mem.of_kind("forward_dropped")
        assert drops and drops[-1]["dropped"] == 3
        assert drops[-1]["proc"] == "w"
    finally:
        sink.close()
        collector.close(drain_s=0.1)


# ------------------------------------------------- obs_trace negotiation

def test_propagate_trace_trace_aware_peer_echoes_and_syncs():
    svc = TrialWorkerService()
    svc.bus = EventBus()
    server = serve_worker(svc, port=0, background=True)
    transport = SocketTransport("127.0.0.1", server.server_address[1])
    bus = EventBus().enable()
    tid = new_trace_id()
    try:
        assert propagate_trace(transport, tid, proc="tcp://w:1", bus=bus)
        assert transport.trace == tid
        assert svc.bus.trace_id == tid       # peer adopted the context
        syncs = bus.events("clock_sync")
        assert len(syncs) == 1
        assert syncs[0]["proc"] == "tcp://w:1"
        assert syncs[0]["rtt_s"] >= 0.0
    finally:
        transport.close()
        server.shutdown()
        svc.close()


def test_propagate_trace_legacy_and_generic_ok_peers_stay_untraced():
    legacy = _serve(lambda req: {"ok": False,
                                 "error": f"unknown op {req.get('op')!r}"})
    generic = _serve(lambda req: {"ok": True})   # ok but no trace echo
    try:
        for server in (legacy, generic):
            t = SocketTransport("127.0.0.1", server.server_address[1],
                                wire="json")
            assert propagate_trace(t, new_trace_id(), proc="p") is False
            assert t.trace is None               # no _trace stamping
            t.close()
    finally:
        legacy.shutdown()
        generic.shutdown()


def test_traced_transport_stamps_trace_metadata_only_on_public_ops():
    seen = []

    def handler(req):
        seen.append(dict(req))
        return {"ok": True}

    server = _serve(handler)
    t = SocketTransport("127.0.0.1", server.server_address[1], wire="json")
    try:
        t.trace = "f" * 16
        t.request({"op": "version"})
        assert seen[-1].get("_trace") == "f" * 16
    finally:
        t.close()
        server.shutdown()


# ------------------------------------------- traced remote-worker stream

def test_traced_remote_worker_forwards_without_duplicate_epochs():
    """The worker ships its own trial_started/per-epoch stream home; the
    driver must NOT synthesize a second epoch stream from the returned
    record — every (trial, epoch) appears exactly once, stamped with the
    worker's proc label."""
    svc = TrialWorkerService()
    svc.bus = EventBus()                # isolate from the process default
    server = serve_worker(svc, port=0, background=True)
    addr = f"tcp://127.0.0.1:{server.server_address[1]}"

    bus = EventBus()
    mem = MemorySink()
    bus.add_sink(mem)
    collector = start_collector(bus)
    ex = WorkerPoolExecutor([RemoteWorker(addr)])
    ex.attach_bus(bus)
    tid = ex.enable_trace(collector=collector.address)
    ex._trace_collector = collector     # closed by ex.close(), CLI-style
    try:
        res = (Experiment(_job()).with_tuner("v1").with_backend("sim")
               .with_scheduler("hyperband").run(executor=ex))
        assert res.best_hparams
        fwd = svc.bus.forward_sink
        assert fwd is not None and fwd.flush(timeout=5.0)
    finally:
        ex.close()
        server.shutdown()
        svc.close()

    epochs = mem.of_kind("epoch_completed")
    assert epochs, "worker epoch stream never arrived"
    keys = [(r["trial_id"], r["epoch"]) for r in epochs]
    assert len(keys) == len(set(keys)), "duplicate epoch events"
    assert all(r["proc"] == addr for r in epochs), \
        "driver synthesized epochs for a traced peer"
    started = mem.of_kind("trial_started")
    assert {r["trial_id"] for r in started} == \
        {r["trial_id"] for r in mem.of_kind("trial_dispatched")}
    rpcs = mem.of_kind("rpc_completed")
    assert any(r["op"] in ("run", "run_many") for r in rpcs)
    assert all(r.get("trace") == tid for r in mem.records
               if r["kind"] != "clock_sync" or r.get("trace"))


# ----------------------------------------------------------- the analyzer

def _rec(kind, ts, seq, **kw):
    r = {"kind": kind, "ts": ts, "seq": seq, "trace": "t" * 16}
    r.update(kw)
    return r


def _synthetic_run():
    """Driver + one skewed worker (+0.5s clock), two trials; t2 is gated
    by t1's completion; t1 resumes once (two segments)."""
    w = "tcp://w:1"
    recs = [
        _rec("clock_sync", 0.0, 1, proc=w, offset_s=0.5, rtt_s=0.001),
        _rec("trial_dispatched", 0.0, 2, proc="driver", trial_id="t1",
             worker=w),
        # worker-stamped events carry the +0.5s skew
        _rec("trial_started", 0.51, 3, proc=w, trial_id="t1", worker=w),
        _rec("epoch_completed", 0.7, 4, proc=w, trial_id="t1", worker=w,
             epoch=0, duration_s=0.19),
        _rec("trial_completed", 0.3, 5, proc="driver", trial_id="t1",
             worker=w, score=0.5),
        _rec("rpc_completed", 0.3, 6, proc="driver", op="run", peer=w,
             duration_s=0.3, overhead_s=0.05),
        # rung resume: second segment of t1
        _rec("trial_dispatched", 0.4, 7, proc="driver", trial_id="t1",
             worker=w),
        _rec("trial_started", 0.91, 8, proc=w, trial_id="t1", worker=w),
        _rec("epoch_completed", 1.1, 9, proc=w, trial_id="t1", worker=w,
             epoch=1, duration_s=0.19),
        _rec("trial_completed", 0.7, 10, proc="driver", trial_id="t1",
             worker=w, score=0.8),
        _rec("rpc_completed", 0.7, 11, proc="driver", op="run", peer=w,
             duration_s=0.3, overhead_s=0.04),
        # t2 dispatched only after t1 fully completed (the gating chain)
        _rec("trial_dispatched", 0.75, 12, proc="driver", trial_id="t2",
             worker=w),
        _rec("trial_completed", 1.0, 13, proc="driver", trial_id="t2",
             worker=w, score=0.9),
        _rec("rpc_completed", 1.0, 14, proc="driver", op="refit",
             peer="store@h:1", duration_s=0.02, overhead_s=0.02),
    ]
    return recs


def test_build_trace_segments_per_rung_resume_and_skew_correction():
    tr = build_trace(_synthetic_run())
    assert set(tr.trials) == {"t1", "t2"}
    t1 = tr.trials["t1"].segments
    assert len(t1) == 2 and tr.trials["t1"].complete
    # skew-corrected: worker 0.51 - 0.5 offset = 0.01 after dispatch 0.0
    assert t1[0].started_ts == pytest.approx(0.01)
    assert t1[0].epochs[0]["ts"] == pytest.approx(0.2)
    assert t1[1].started_ts == pytest.approx(0.41)
    # each resume's epochs landed in its own segment
    assert [e["epoch"] for e in t1[0].epochs] == [0]
    assert [e["epoch"] for e in t1[1].epochs] == [1]
    assert not tr.orphans


def test_build_trace_slots_events_despite_residual_skew():
    """A worker start that lands a hair BEFORE its dispatch after skew
    correction (residual estimation error) still joins the segment."""
    w = "tcp://w:1"
    recs = [
        _rec("trial_dispatched", 1.0, 1, proc="driver", trial_id="t",
             worker=w),
        _rec("trial_started", 0.9985, 2, proc=w, trial_id="t", worker=w),
        _rec("trial_completed", 1.4, 3, proc="driver", trial_id="t",
             worker=w, score=1.0),
    ]
    tr = build_trace(recs)
    assert not tr.orphans
    seg = tr.trials["t"].segments[0]
    assert seg.started_ts == pytest.approx(0.9985)
    assert seg.queue_wait_s == 0.0      # clamped, never negative


def test_analyze_trace_breakdown_critical_path_and_stragglers():
    report = analyze_trace(_synthetic_run())
    assert report["trace_ids"] == ["t" * 16]
    assert report["n_trials"] == 2 and report["n_segments"] == 3
    assert report["n_orphans"] == 0
    assert report["clock_offsets"]["tcp://w:1"] == pytest.approx(0.5)
    b = report["breakdown"]
    assert b["wall_s"] == pytest.approx(1.0)
    assert b["rpc_overhead_s"] == pytest.approx(0.09)   # run ops only
    assert b["store_wait_s"] == pytest.approx(0.02)
    assert b["queue_wait_s"] == pytest.approx(0.01 + 0.01)
    # the gating chain: t1 seg1 -> t1 seg2 -> t2
    cp = report["critical_path"]
    assert cp["n_segments"] == 3
    assert [s["trial_id"] for s in cp["segments"]] == ["t1", "t1", "t2"]
    assert cp["length_s"] == pytest.approx(1.0)
    assert report["stragglers"][0]["worker"] == "tcp://w:1"
    # one worker, serial segments: util <= 100% and busy = union of spans
    row = report["workers"][0]
    assert row["busy_s"] == pytest.approx(0.3 + 0.3 + 0.25)
    assert row["util"] <= 1.0
    text = render_report(report)
    assert "wall-time breakdown" in text and "critical path" in text
    json.dumps(report)                  # the whole report is JSON-safe


def test_analyze_trace_flags_orphans_and_forward_drops():
    recs = _synthetic_run() + [
        _rec("epoch_completed", 0.5, 90, proc="tcp://w:1",
             trial_id="ghost", worker="tcp://w:1", epoch=0,
             duration_s=0.1),
        _rec("forward_dropped", 0.6, 91, proc="tcp://w:1", dropped=7),
    ]
    report = analyze_trace(recs)
    assert report["n_orphans"] == 1
    assert report["orphan_trials"] == ["ghost"]
    assert report["forward_dropped"] == 7
    text = render_report(report)
    assert "ORPHAN" in text and "dropped" in text


# ------------------------------------------------- satellite: torn traces

def test_read_trace_tolerates_torn_final_line(tmp_path):
    good = json.dumps({"kind": "store_refit", "ts": 1.0, "seq": 1,
                       "version": 1})
    for tail in ('{"kind": "trial_co', '{"kind": "trial_co\n'):
        p = tmp_path / "t.jsonl"
        p.write_text(good + "\n" + tail)
        assert [r["kind"] for r in read_trace(str(p))] == ["store_refit"]
    # a torn line that is NOT final still raises: that is corruption
    p = tmp_path / "bad.jsonl"
    p.write_text('{"kind": "trial_co\n' + good + "\n")
    with pytest.raises(ValueError):
        read_trace(str(p))


# ----------------------------------- satellite: self-healing obs client

def test_obs_client_waits_out_a_slow_endpoint():
    port = _free_port()
    client = ObsClient(f"tcp://127.0.0.1:{port}", connect_retries=40,
                       retry_backoff_s=0.05)
    out = {}

    def scrape():
        try:
            out["text"] = client.metrics()
        except Exception as e:                      # noqa: BLE001
            out["err"] = e

    t = threading.Thread(target=scrape, daemon=True)
    t.start()
    time.sleep(0.4)                     # client is already retrying
    server = serve_obs(EventBus(), port=port, background=True)
    try:
        t.join(timeout=10.0)
        assert "repro_events_total" in out.get("text", ""), out
    finally:
        client.close()
        server.shutdown()


def test_obs_client_raises_unreachable_after_budget():
    port = _free_port()                 # nothing ever listens here
    client = ObsClient(f"tcp://127.0.0.1:{port}", connect_retries=1,
                       retry_backoff_s=0.01)
    with pytest.raises(ObsUnreachable, match="unreachable"):
        client.metrics()
    client.close()


# ------------------------------------------------------ satellite: CLI

@pytest.fixture
def obs_endpoint():
    bus = EventBus()
    server = serve_obs(bus, port=0, background=True)
    from repro.obs.events import StoreRefit
    bus.emit(StoreRefit(version=1, n_entries=3))
    bus.emit(StoreRefit(version=2, n_entries=5))
    yield f"tcp://127.0.0.1:{server.server_address[1]}"
    server.shutdown()


def test_cli_tail_once(obs_endpoint, capsys):
    assert obs_cli.main(["tail", obs_endpoint, "--once"]) == 0
    lines = [json.loads(l) for l in
             capsys.readouterr().out.strip().splitlines()]
    assert [r["kind"] for r in lines] == ["store_refit", "store_refit"]


def test_cli_metrics(obs_endpoint, capsys):
    assert obs_cli.main(["metrics", obs_endpoint]) == 0
    out = capsys.readouterr().out
    assert "repro_events_total 2" in out
    assert 'repro_events{kind="store_refit"} 2' in out


def test_cli_bad_endpoint_errors_cleanly(capsys):
    port = _free_port()
    for cmd in (["tail", f"tcp://127.0.0.1:{port}", "--once",
                 "--retries", "1"],
                ["metrics", f"tcp://127.0.0.1:{port}", "--retries", "1"]):
        assert obs_cli.main(cmd) == 1
        err = capsys.readouterr().err
        assert "error:" in err and "unreachable" in err


def test_cli_chaos_list(capsys):
    assert obs_cli.main(["chaos", "--list"]) == 0
    out = capsys.readouterr().out
    assert "sigkill_worker" in out


def test_cli_analyze_table_and_json(tmp_path, capsys):
    path = tmp_path / "trace.jsonl"
    sink = JsonlSink(str(path))
    for r in _synthetic_run():
        sink(r)
    sink.close()
    assert obs_cli.main(["analyze", str(path)]) == 0
    out = capsys.readouterr().out
    assert "wall-time breakdown" in out and "critical path" in out
    assert obs_cli.main(["analyze", str(path), "--json"]) == 0
    report = json.loads(capsys.readouterr().out)
    assert report["n_trials"] == 2

    assert obs_cli.main(["analyze", str(tmp_path / "missing.jsonl")]) == 1
    assert "error:" in capsys.readouterr().err
    empty = tmp_path / "empty.jsonl"
    empty.write_text("")
    assert obs_cli.main(["analyze", str(empty)]) == 1
    assert "empty" in capsys.readouterr().err


# --------------------------------------- acceptance: distributed end-to-end

@pytest.mark.slow
def test_distributed_run_under_trace_yields_one_merged_timeline(tmp_path):
    """Acceptance: a real ``python -m repro.worker`` subprocess + a TCP
    store, driven through the ``--workers``/``--trace`` launch path, leave
    ONE merged trace from which analyze reconstructs every trial's full
    span tree — worker-side starts/epochs joined to driver-side
    dispatch/completion, no orphans — plus breakdown and critical path."""
    import os
    from repro.launch.sysargs import executor_from_args

    trace_path = str(tmp_path / "run_trace.jsonl")
    store_svc = GroundTruthService()
    store_svc.bus = EventBus()          # isolate from the process default
    store_srv = GroundTruthTCPServer(("127.0.0.1", 0), store_svc)
    threading.Thread(target=store_srv.serve_forever, daemon=True).start()
    s_host, s_port = store_srv.server_address[:2]

    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.worker", "--port", "0"],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
        env={**os.environ, "PYTHONPATH": "src"}, cwd=repo_root)
    try:
        line = proc.stdout.readline()
        assert "trial worker on" in line, line
        wport = int(line.split(" on ", 1)[1].split()[0].rsplit(":", 1)[1])
        worker_proc = f"tcp://127.0.0.1:{wport}"

        args = argparse.Namespace(
            executor="serial", parallelism=1, cluster_nodes=4,
            straggler_prob=0.0, backends=None, shard_capacity=1,
            workers=worker_proc, coordinator=None, trace=trace_path,
            wire="auto")
        ex = executor_from_args(args)
        res = (Experiment(_job(epochs=6))
               .with_tuner("pipetune", max_probes=4).with_backend("sim")
               .with_groundtruth(StoreClient(SocketTransport(s_host,
                                                             s_port)))
               .with_scheduler("random", n_trials=4).run(executor=ex))
        assert res.best_hparams
        time.sleep(0.5)                 # let the worker's flusher tick
        ex.close()                      # drains + closes the collector
    finally:
        proc.terminate()
        proc.wait(timeout=10)
        store_srv.shutdown()
        store_svc.close()

    records = load_events([trace_path])
    report = analyze_trace(records)
    assert len(report["trace_ids"]) == 1, report["trace_ids"]
    assert "driver" in report["procs"] and worker_proc in report["procs"]
    # every trial's span tree is complete: dispatched + started + completed
    assert report["n_orphans"] == 0
    assert report["n_trials"] >= 4
    for tid, segs in report["trials"].items():
        for seg in segs:
            assert not seg["orphan"], (tid, seg)
            assert seg["completed_ts"] is not None, (tid, seg)
        assert any(s["started_ts"] is not None for s in segs), \
            f"no worker-side start for {tid}"
    # worker-side epoch stream arrived exactly once per epoch
    epochs = [r for r in records if r.get("kind") == "epoch_completed"]
    keys = [(r["trial_id"], r["epoch"]) for r in epochs]
    assert epochs and len(keys) == len(set(keys))
    assert all(r.get("proc") == worker_proc for r in epochs)
    # store RPCs were traced (receipts against the store peer label)
    assert any(str(r.get("peer", "")).startswith("store@")
               for r in records if r.get("kind") == "rpc_completed")
    assert report["breakdown"]["wall_s"] > 0
    assert report["critical_path"]["n_segments"] >= 1
    assert report["workers"] and report["workers"][0]["util"] <= 1.0
