"""Layer invariants: rope, chunked-vs-direct attention, norms, MoE."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.models import layers, moe as moe_lib


def test_rope_preserves_norm():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 2, 2, 64))
    pos = jnp.arange(8)[None].astype(jnp.int32)
    y = layers.apply_rope(x, pos)
    np.testing.assert_allclose(
        np.asarray(jnp.linalg.norm(x, axis=-1)),
        np.asarray(jnp.linalg.norm(y, axis=-1)), rtol=1e-5)


def test_rope_relative_property():
    """<rope(q,p1), rope(k,p2)> depends only on p1 - p2."""
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 1, 32))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 32))

    def score(p1, p2):
        qp = layers.apply_rope(q, jnp.array([[p1]], jnp.int32))
        kp = layers.apply_rope(k, jnp.array([[p2]], jnp.int32))
        return float(jnp.einsum("bskgd,btkd->b", qp, kp)[0])
    assert score(5, 3) == pytest.approx(score(9, 7), rel=1e-4)
    assert score(5, 3) != pytest.approx(score(5, 4), rel=1e-3)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 2), st.sampled_from([48, 64, 96]),
       st.sampled_from([None, 16]), st.booleans())
def test_chunked_equals_direct(B, S, window, causal):
    ks = jax.random.split(jax.random.PRNGKey(S), 3)
    q = jax.random.normal(ks[0], (B, S, 2, 2, 32))
    k = jax.random.normal(ks[1], (B, S, 2, 32))
    v = jax.random.normal(ks[2], (B, S, 2, 32))
    a = layers.attention(q, k, v, causal=causal, window=window)
    b = layers.chunked_attention(q, k, v, causal=causal, window=window,
                                 q_chunk=32, kv_chunk=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=2e-5)


def test_rmsnorm_scale_invariance():
    p = layers.init_rmsnorm(16)
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 16))
    np.testing.assert_allclose(np.asarray(layers.rmsnorm(p, x)),
                               np.asarray(layers.rmsnorm(p, x * 7.0)),
                               rtol=1e-4, atol=1e-5)


def test_moe_high_capacity_matches_dense_mixture():
    """With capacity >> needed, MoE output == explicit weighted expert sum."""
    cfg = moe_lib.MoEConfig(d_model=16, d_ff=32, n_experts=4, top_k=2,
                            capacity_factor=16.0)
    p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, 16))
    y, aux = moe_lib.apply_moe(p, x, cfg)
    # explicit: for each token route to top2 experts, weighted sum
    logits = jnp.einsum("btd,de->bte", x, p["router"])
    probs = jax.nn.softmax(logits, -1)
    w, idx = jax.lax.top_k(probs, 2)
    w = w / w.sum(-1, keepdims=True)

    def expert(e, t):
        g = jax.nn.silu(t @ p["w_gate"][e]) * (t @ p["w_up"][e])
        return g @ p["w_down"][e]
    y_exp = jnp.zeros_like(x)
    for b in range(2):
        for t in range(6):
            acc = jnp.zeros((16,))
            for j in range(2):
                acc += w[b, t, j] * expert(idx[b, t, j], x[b, t])
            y_exp = y_exp.at[b, t].set(acc)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_exp), rtol=1e-4,
                               atol=1e-4)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens():
    cfg = moe_lib.MoEConfig(d_model=8, d_ff=16, n_experts=2, top_k=1,
                            capacity_factor=0.25)
    p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 8))
    y, _ = moe_lib.apply_moe(p, x, cfg)
    # some token outputs must be exactly zero (dropped by capacity)
    norms = jnp.linalg.norm(y[0], axis=-1)
    assert float(norms.min()) == 0.0
    assert float(norms.max()) > 0.0


def test_shared_experts_always_active():
    cfg = moe_lib.MoEConfig(d_model=8, d_ff=16, n_experts=4, top_k=1,
                            n_shared=2, capacity_factor=0.01)
    p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 8))
    y, _ = moe_lib.apply_moe(p, x, cfg)
    # capacity ~0 drops all routed tokens, but shared branch still fires
    assert float(jnp.linalg.norm(y)) > 0
