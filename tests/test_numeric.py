"""Type-III workloads: real convergence + PipeTune integration."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GroundTruth, PipeTune, SystemSpace
from repro.core.numeric_backend import NumericBackend
from repro.models import numeric


@pytest.mark.parametrize("wl", ["jacobi-rodinia", "spkmeans-rodinia",
                                "bfs-rodinia"])
def test_numeric_workloads_converge(wl):
    cfg = numeric.CONFIGS[wl]
    be = NumericBackend()
    ts = be.init_trial(wl, {}, seed=0)
    accs = []
    for _ in range(4):
        ts, res = be.run_epoch(ts, {"precision": "fp32", "microbatches": 1})
        accs.append(res.accuracy)
    assert accs[-1] >= accs[0] - 1e-6       # monotone-ish progress
    assert accs[-1] > 0.3                   # genuinely converging


def test_pipetune_runs_on_numeric_backend():
    sspace = SystemSpace(remat=("none",), microbatches=(1, 2),
                         precision=("fp32",))
    pt = PipeTune(NumericBackend(), sspace, groundtruth=GroundTruth(),
                  max_probes=2)
    rec = pt.run_trial("jacobi-rodinia", "t0", {}, 5)
    assert len(rec.epochs) == 5
    assert rec.epochs[-1].accuracy > 0.3
    assert rec.probe_epochs == 2            # probing happened on short epochs


def test_numeric_profiles_differ_from_classifiers():
    """Type-III profiles must be distinguishable (Fig 8/12 premise)."""
    be = NumericBackend()
    ts = be.init_trial("jacobi-rodinia", {}, seed=0)
    _, res = be.run_epoch(ts, {"precision": "fp32"})
    v = res.profile.vector()
    assert v.shape == (58,)
    assert np.isfinite(v).all()
