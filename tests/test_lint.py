"""Tests for the repro.lint static-analysis subsystem.

Covers: golden findings per pass against the fixture files, seeded
violations injected into live modules, suppression + baseline round-trips,
JSON schema stability, the CLI, and the meta-test that the committed tree
is lint-clean modulo the committed baseline.
"""
import json
import os
import subprocess
import sys

import pytest

from repro.lint import (
    Baseline,
    Finding,
    LintConfig,
    Project,
    all_passes,
    default_config,
    render_json,
    run_lint,
)

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(HERE)
SRC = os.path.join(REPO, "src", "repro")
FIXTURES = os.path.join(HERE, "lint_fixtures")


def fixture_source(name):
    with open(os.path.join(FIXTURES, name), "r", encoding="utf-8") as fh:
        return fh.read()


def rules_of(findings):
    out = {}
    for f in findings:
        out[f.rule] = out.get(f.rule, 0) + 1
    return out


# --------------------------------------------------------------------------
# golden findings per pass


def test_determinism_fixture_golden():
    cfg = LintConfig(deterministic_modules=())  # fixture opts in via marker
    project = Project.from_sources(
        {"fixture_determinism.py": fixture_source("fixture_determinism.py")},
        cfg,
    )
    findings, suppressed = run_lint(project, select=["determinism"])
    assert rules_of(findings) == {"DET001": 1, "DET002": 4, "DET003": 1,
                                  "DET004": 2}
    assert suppressed == 1  # the inline-disabled time.time()
    det1 = [f for f in findings if f.rule == "DET001"]
    assert det1[0].symbol == "wall_clock"
    assert "time.time" in det1[0].message


def test_wire_fixture_golden():
    cfg = LintConfig(
        clients={"FixtureClient": ("FixtureService",)},
        broadcast_senders={},
        literal_dispatch_servers=(),
        ops_tables={"FixtureService": "_OPS"},
    )
    project = Project.from_sources(
        {"fixture_wire.py": fixture_source("fixture_wire.py")}, cfg
    )
    findings, _ = run_lint(project, select=["wire"])
    counts = rules_of(findings)
    assert counts["WIRE001"] == 1
    assert counts["WIRE003"] == 2          # set value + non-string key
    assert counts["WIRE004"] == 1          # _op_add missing from _OPS
    unsent = {f.message.split("'")[1] for f in findings
              if f.rule == "WIRE002"}
    assert unsent == {"add", "unused"}
    w1 = [f for f in findings if f.rule == "WIRE001"][0]
    assert "missing_op" in w1.message and w1.severity == "error"


def test_locks_fixture_golden():
    cfg = LintConfig(
        attr_types={
            ("FixtureBusA", "peer"): ("FixtureBusB",),
            ("FixtureBusB", "pool"): ("FixtureBusA",),
        }
    )
    project = Project.from_sources(
        {"fixture_locks.py": fixture_source("fixture_locks.py")}, cfg
    )
    findings, _ = run_lint(project, select=["locks"])
    counts = rules_of(findings)
    assert counts == {"LOCK001": 1, "LOCK002": 1}
    lock1 = [f for f in findings if f.rule == "LOCK001"][0]
    assert lock1.symbol == "FixturePool.close"
    assert "workers" in lock1.message
    # _op_retire pops workers too, but only under handle's dynamic
    # dispatch while locked — must NOT be flagged
    assert not any(f.symbol.endswith("_op_retire") for f in findings)


def test_events_fixture_golden():
    cfg = LintConfig(
        event_module="fixture_events.py",
        kind_check_paths=("fixture_events_use.py",),
        kind_dispatchers={"dispatch": ()},
    )
    project = Project.from_sources(
        {
            "fixture_events.py": fixture_source("fixture_events.py"),
            "fixture_events_use.py": fixture_source("fixture_events_use.py"),
        },
        cfg,
    )
    findings, _ = run_lint(project, select=["events"])
    counts = rules_of(findings)
    assert counts == {"EVT001": 1, "EVT002": 2, "EVT003": 1, "EVT004": 1,
                      "EVT005": 1}
    evt3 = [f for f in findings if f.rule == "EVT003"][0]
    assert "fixture_startd" in evt3.message
    evt5 = [f for f in findings if f.rule == "EVT005"][0]
    assert "fixture_orphan" in evt5.message


def test_serve_fixture_golden():
    cfg = LintConfig(
        serve_scopes={
            "FixtureServer": ("_on_readable", "_on_writable", "_run_handler")
        },
        serve_paths=("fixture",),
    )
    project = Project.from_sources(
        {"fixture_serve.py": fixture_source("fixture_serve.py")}, cfg
    )
    findings, _ = run_lint(project, select=["serve", "capability"])
    counts = rules_of(findings)
    assert counts == {"EXC001": 2, "EXC002": 1, "CAP001": 1}
    descs = {f.message.split(" in serve scope")[0] for f in findings
             if f.rule == "EXC001"}
    assert descs == {"socket op .recv()", "codec .encode()"}


# --------------------------------------------------------------------------
# seeded violations in live modules


def _live_sources():
    project = Project.from_dir(SRC, default_config())
    return {path: mod.source for path, mod in project.modules.items()}


def test_seeded_wall_clock_in_engine():
    sources = _live_sources()
    assert "cluster/engine.py" in sources
    clean = Project.from_sources(sources, default_config())
    before, _ = run_lint(clean, select=["determinism"])
    assert not [f for f in before if f.path == "cluster/engine.py"]

    sources["cluster/engine.py"] += (
        "\n\ndef _seeded_violation():\n    return time.time()\n"
    )
    mutated = Project.from_sources(sources, default_config())
    after, _ = run_lint(mutated, select=["determinism"])
    hits = [f for f in after if f.path == "cluster/engine.py"]
    assert len(hits) == 1 and hits[0].rule == "DET001"
    assert hits[0].symbol == "_seeded_violation"


def test_seeded_op_removed_from_ops_table():
    sources = _live_sources()
    src = sources["service/service.py"]
    assert '"add"' in src.split("\n", 60)[0] or '"add"' in src
    sources["service/service.py"] = src.replace(
        '"add",', "", 1
    )  # drop "add" from the module-level _OPS gate
    mutated = Project.from_sources(sources, default_config())
    findings, _ = run_lint(mutated, select=["wire"])
    w4 = [f for f in findings if f.rule == "WIRE004"]
    assert any("_op_add" in f.message for f in w4), w4


def test_seeded_kernel_db_removed_from_ops_table():
    sources = _live_sources()
    src = sources["service/service.py"]
    assert '"kernel_db"' in src
    sources["service/service.py"] = src.replace(
        ', "kernel_db"', "", 1
    )  # drop the find-db op from the module-level _OPS gate
    mutated = Project.from_sources(sources, default_config())
    findings, _ = run_lint(mutated, select=["wire"])
    w4 = [f for f in findings if f.rule == "WIRE004"]
    assert any("_op_kernel_db" in f.message for f in w4), w4


def test_seeded_kernel_db_client_op_typo():
    # the StoreClient kernel helpers are plain dict-literal sends, so the
    # existing clients mapping cross-checks them against the service _OPS
    # gate with no lint-config edits: a typo'd op name is a static error
    sources = _live_sources()
    src = sources["service/transport.py"]
    assert src.count('"op": "kernel_db"') == 3
    sources["service/transport.py"] = src.replace(
        '"op": "kernel_db"', '"op": "kernel_bd"', 1)
    mutated = Project.from_sources(sources, default_config())
    findings, _ = run_lint(mutated, select=["wire"])
    w1 = [f for f in findings if f.rule == "WIRE001"
          and "kernel_bd" in f.message]
    assert len(w1) == 1, [f.message for f in findings]


def test_seeded_unlocked_write_in_worker_service():
    sources = _live_sources()
    sources["service/worker.py"] += (
        "\n\nclass _SeededRace:\n"
        "    def __init__(self):\n"
        "        self._lock = threading.Lock()\n"
        "        self.state = []\n"
        "    def locked_add(self, x):\n"
        "        with self._lock:\n"
        "            self.state.append(x)\n"
        "    def wipe(self):\n"
        "        self.state = []\n"
    )
    mutated = Project.from_sources(sources, default_config())
    findings, _ = run_lint(mutated, select=["locks"])
    hits = [f for f in findings if f.symbol == "_SeededRace.wipe"]
    assert len(hits) == 1 and hits[0].rule == "LOCK001"


# --------------------------------------------------------------------------
# suppression + baseline


def test_inline_suppression_modes():
    cfg = LintConfig(deterministic_modules=("mod.py",))
    body = (
        "import time\n"
        "def a():\n"
        "    return time.time()  # lint: disable=DET001\n"
        "def b():\n"
        "    # lint: disable-next=determinism\n"
        "    return time.time()\n"
        "def c():\n"
        "    return time.time()\n"
    )
    findings, suppressed = run_lint(
        Project.from_sources({"mod.py": body}, cfg), select=["determinism"]
    )
    assert suppressed == 2
    assert [f.symbol for f in findings] == ["c"]

    filewide = "# lint: disable-file=all\n" + body
    findings, suppressed = run_lint(
        Project.from_sources({"mod.py": filewide}, cfg),
        select=["determinism"],
    )
    assert findings == [] and suppressed == 3


def test_baseline_roundtrip(tmp_path):
    f1 = Finding(path="a.py", line=10, col=0, rule="DET001",
                 severity="error", message="m1", symbol="A.f")
    f2 = Finding(path="b.py", line=3, col=4, rule="LOCK001",
                 severity="error", message="m2", symbol="B.g")
    path = str(tmp_path / "baseline.json")
    Baseline.from_findings([f1, f2]).save(path)
    loaded = Baseline.load(path)
    new, old = loaded.split([f1, f2])
    assert new == [] and len(old) == 2

    # line drift does not invalidate entries; message drift does
    drifted = Finding(path="a.py", line=99, col=7, rule="DET001",
                      severity="error", message="m1", symbol="A.f")
    changed = Finding(path="a.py", line=10, col=0, rule="DET001",
                      severity="error", message="other", symbol="A.f")
    new, old = loaded.split([drifted, changed])
    assert old == [drifted] and new == [changed]


def test_baseline_rewrite_preserves_reasons(tmp_path):
    f1 = Finding(path="a.py", line=1, col=0, rule="DET001",
                 severity="error", message="m1", symbol="A.f")
    path = str(tmp_path / "baseline.json")
    first = Baseline.from_findings([f1])
    first.entries[0]["reason"] = "because physics"
    first.save(path)
    rewritten = Baseline.from_findings([f1], previous=Baseline.load(path))
    assert rewritten.entries[0]["reason"] == "because physics"


# --------------------------------------------------------------------------
# JSON schema


def test_json_report_schema_stable():
    cfg = LintConfig(deterministic_modules=("mod.py",))
    findings, suppressed = run_lint(
        Project.from_sources(
            {"mod.py": "import time\nT = time.time()\n"}, cfg
        ),
        select=["determinism"],
    )
    doc = json.loads(
        render_json(findings, baselined=[], suppressed=suppressed,
                    passes=["determinism"])
    )
    assert set(doc) == {"schema", "passes", "summary", "findings",
                        "baselined"}
    assert doc["schema"] == "repro.lint/1"
    assert set(doc["summary"]) == {"findings", "errors", "warnings",
                                   "baselined", "suppressed"}
    assert doc["summary"]["findings"] == 1
    (rec,) = doc["findings"]
    assert set(rec) == {"rule", "severity", "path", "line", "col", "symbol",
                        "message", "pass"}


# --------------------------------------------------------------------------
# meta: the committed tree is clean modulo the committed baseline


def test_live_tree_clean_modulo_baseline():
    project = Project.from_dir(SRC, default_config())
    findings, _ = run_lint(project)
    baseline = Baseline.load(os.path.join(REPO, "lint-baseline.json"))
    new, _ = baseline.split(findings)
    assert new == [], "un-baselined findings:\n" + "\n".join(
        "%s:%d %s %s" % (f.path, f.line, f.rule, f.message) for f in new
    )


def test_all_five_passes_registered():
    names = {cls.name for cls in all_passes()}
    assert {"determinism", "wire", "locks", "events", "serve",
            "capability"} <= names


# --------------------------------------------------------------------------
# CLI


def _run_cli(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.lint", *args],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=120,
    )


def test_cli_clean_tree_exits_zero():
    res = _run_cli("--fail-on-findings",
                   "--baseline", os.path.join(REPO, "lint-baseline.json"))
    assert res.returncode == 0, res.stdout + res.stderr
    assert "0 finding(s)" in res.stdout


def test_cli_json_report(tmp_path):
    out = str(tmp_path / "report.json")
    res = _run_cli("--json", "--json-out", out)
    assert res.returncode == 0, res.stdout + res.stderr
    doc = json.loads(res.stdout)
    assert doc["schema"] == "repro.lint/1"
    with open(out) as fh:
        assert json.load(fh) == doc


def test_cli_fails_on_findings():
    res = _run_cli(FIXTURES, "--no-baseline", "--select",
                   "capability")
    assert res.returncode == 1
    assert "CAP001" in res.stdout
