"""Shared tuning service (PR 3 acceptance surface): GroundTruthService
protocol + journal recovery, in-proc/socket transports with client-side
centroid caching, socket == in-proc bit-identity on a warm store, the
sharded multi-backend executor's serial parity, and the MetricsStore
flush-on-close satellites."""
import json
import os
import shutil
import threading

import numpy as np
import pytest

from repro.api import Experiment
from repro.cluster.engine import ClusterConfig, EventEngine
from repro.cluster.sim import SimBackend, SimSystemSpace
from repro.core import GroundTruth, GroundTruthError, PipeTune
from repro.core.executor import SerialTrialExecutor
from repro.core.job import HPTJob, Param, SearchSpace
from repro.core.store import MetricsStore
from repro.service import (GroundTruthService, GroundTruthTCPServer,
                           InprocTransport, ShardedTrialExecutor,
                           SocketTransport, StoreClient, StoreError)


def _profile(seed, block=0, level=10.0, jitter=0.05):
    rng = np.random.RandomState(seed)
    base = np.zeros(58)
    base[block * 5:(block + 1) * 5] = level
    return base + rng.randn(58) * jitter


def _space():
    return SearchSpace([
        Param("batch_size", "choice", choices=(32, 64, 256, 1024)),
        Param("learning_rate", "log", 0.001, 0.1),
    ])


def _job(seed=0, epochs=9):
    return HPTJob(workload="lenet-mnist", space=_space(), max_epochs=epochs,
                  seed=seed)


@pytest.fixture
def tcp_server():
    """(service, client) over a real TCP connection on an ephemeral port."""
    made = []

    def make(service):
        server = GroundTruthTCPServer(("127.0.0.1", 0), service)
        threading.Thread(target=server.serve_forever, daemon=True).start()
        client = StoreClient(
            SocketTransport("127.0.0.1", server.server_address[1]))
        made.append((server, client))
        return client

    yield make
    for server, client in made:
        client.close()
        server.shutdown()


# ----------------------------------------------------------------- protocol

def test_service_protocol_roundtrip():
    svc = GroundTruthService()
    client = StoreClient(InprocTransport(svc))
    assert client.version() == 0
    for i in range(3):
        client.add(_profile(i), "wl-a", {"chips": 4}, 0.9)
    score, cfg = client.lookup(_profile(99))
    assert cfg == {"chips": 4} and score > 0
    assert (client.hits, client.misses) == (1, 0)
    snap = client.snapshot()
    assert snap["n_entries"] == 3 and snap["model"] is not None
    # different workload family: a miss, counted client-side
    score_b, cfg_b = client.lookup(_profile(7, block=3, level=40.0))
    assert cfg_b is None and score_b == 0.0
    assert client.misses == 1


def test_service_versions_are_monotonic_per_refit():
    svc = GroundTruthService()
    client = StoreClient(InprocTransport(svc))
    versions = [client.add(_profile(i), "w", {"chips": 4}, 0.5)
                for i in range(3)]
    assert versions == sorted(versions) and len(set(versions)) == 3
    assert client.refit() > versions[-1]
    # refit=False defers the version bump to the next refit
    v = client.add(_profile(9), "w", {"chips": 4}, 0.5, refit=False)
    assert v == client.version()
    assert client.refit() > v


def test_service_rejects_unknown_op_and_bad_requests():
    svc = GroundTruthService()
    assert not svc.handle({"op": "drop_all"})["ok"]
    assert not svc.handle({"op": "add", "profile": [1.0]})["ok"]  # no fields
    client = StoreClient(InprocTransport(svc))
    with pytest.raises(StoreError):
        client._request({"op": "nope"})


def test_service_lookup_matches_bare_groundtruth():
    """The client's cached-model evaluation is the same arithmetic as a
    direct GroundTruth.lookup — scores equal bit for bit."""
    gt = GroundTruth()
    svc = GroundTruthService()
    client = StoreClient(InprocTransport(svc))
    for i in range(4):
        p = _profile(i)
        gt.add(p, "w", {"chips": 4 + i}, 0.5 + 0.1 * i)
        client.add(p, "w", {"chips": 4 + i}, 0.5 + 0.1 * i)
    for s in range(20, 30):
        probe = _profile(s, jitter=1.0)
        assert client.lookup(probe) == gt.lookup(probe)


# ------------------------------------------------------------------ journal

def test_journal_replay_recovers_store(tmp_path):
    path = str(tmp_path / "gt.jsonl")
    svc = GroundTruthService(path=path)
    client = StoreClient(InprocTransport(svc))
    for i in range(4):
        client.add(_profile(i), "w", {"chips": 8}, 0.7)
    probe = _profile(50)
    expected = client.lookup(probe)
    svc.close()

    svc2 = GroundTruthService(path=path)
    assert len(svc2.store.entries) == 4
    assert StoreClient(InprocTransport(svc2)).lookup(probe) == expected


def test_journal_torn_tail_is_dropped_but_corruption_raises(tmp_path):
    path = str(tmp_path / "gt.jsonl")
    svc = GroundTruthService(path=path)
    for i in range(3):
        svc.handle({"op": "add", "profile": _profile(i).tolist(),
                    "workload": "w", "sys_config": {"chips": 4},
                    "objective": 0.5})
    svc.close()
    # crash mid-append: a torn final record without newline is tolerated
    with open(path, "a") as f:
        f.write('{"op": "add", "profile": [1.0, 2.')
    svc2 = GroundTruthService(path=path)
    assert len(svc2.store.entries) == 3
    # recovery repaired the journal: appending after it must not corrupt
    svc2.handle({"op": "add", "profile": _profile(9).tolist(),
                 "workload": "w", "sys_config": {"chips": 8},
                 "objective": 0.6})
    svc2.close()
    svc2b = GroundTruthService(path=path)
    assert len(svc2b.store.entries) == 4
    svc2b.close()
    # but a mangled record in the middle is a hard, explained error
    lines = open(path).read().splitlines()
    lines[1] = lines[1][:20]
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")
    with pytest.raises(GroundTruthError, match="--store-reset"):
        GroundTruthService(path=path)
    # the escape hatch: reset discards the journal and starts empty
    svc3 = GroundTruthService(path=path, reset=True)
    assert len(svc3.store.entries) == 0
    svc3.close()


def test_journal_refuses_groundtruth_save_file_without_truncating(tmp_path):
    """A GroundTruth.save() store pointed at the journal flag must fail
    loudly and leave the file byte-identical — not be 'recovered' into an
    empty journal (that would silently destroy the persisted optima)."""
    path = str(tmp_path / "gt.json")
    gt = GroundTruth()
    for i in range(3):
        gt.add(_profile(i), "w", {"chips": 4}, 0.5)
    gt.save(path)
    before = open(path).read()
    with pytest.raises(GroundTruthError, match="GroundTruth.save"):
        GroundTruthService(path=path)
    assert open(path).read() == before
    # same for a legacy format-1 list payload: clear error, not a raw
    # AttributeError
    path1 = str(tmp_path / "gt1.json")
    with open(path1, "w") as f:
        json.dump([{"profile": _profile(0).tolist(), "workload": "w",
                    "sys_config": {}, "objective": 0.5}], f)
    with pytest.raises(GroundTruthError, match="--store-reset"):
        GroundTruthService(path=path1)


def test_add_without_refit_does_not_break_lookup():
    """Entries appended with refit=False stay invisible until the next
    refit instead of corrupting the model's label indexing."""
    gt = GroundTruth()
    for i in range(3):
        gt.add(_profile(i), "w", {"chips": 4}, 0.5)
    gt.add(_profile(8, block=3, level=40.0), "w2", {"chips": 16}, 0.9,
           refit=False)
    score, cfg = gt.lookup(_profile(9, block=3, level=40.0))
    assert cfg is None                              # not fitted yet: miss
    gt.refit()
    score, cfg = gt.lookup(_profile(9, block=3, level=40.0))
    assert cfg == {"chips": 16}                     # visible after refit


# ----------------------------------------------------- GroundTruth save/load

def test_groundtruth_save_load_keeps_counters_and_normalization(tmp_path):
    p = str(tmp_path / "gt.json")
    gt = GroundTruth()
    for i in range(3):
        gt.add(_profile(i), "w", {"chips": 4}, 0.9)
    gt.lookup(_profile(11))                        # hit
    gt.lookup(_profile(12, block=5, level=77.0))   # miss
    gt.save(p)
    gt2 = GroundTruth(path=p)
    assert (gt2.hits, gt2.misses) == (gt.hits, gt.misses) == (1, 1)
    np.testing.assert_array_equal(gt2._mu, gt._mu)
    np.testing.assert_array_equal(gt2._sigma, gt._sigma)
    for s in range(30, 40):
        probe = _profile(s, jitter=0.5)
        assert gt2.centroid_model().evaluate(probe) == \
            gt.centroid_model().evaluate(probe)


def test_groundtruth_load_corrupt_file_raises(tmp_path):
    p = str(tmp_path / "gt.json")
    with open(p, "w") as f:
        f.write('{"entries": [{"profile": [1.0')
    with pytest.raises(GroundTruthError, match="--store-reset"):
        GroundTruth(path=p)
    # corrupt *metadata* in an otherwise-parseable file is the same error,
    # not a raw TypeError
    with open(p, "w") as f:
        json.dump({"entries": [], "hits": None}, f)
    with pytest.raises(GroundTruthError, match="--store-reset"):
        GroundTruth(path=p)


def test_groundtruth_load_format1_list_payload(tmp_path):
    p = str(tmp_path / "gt.json")
    entries = [{"profile": _profile(i).tolist(), "workload": "w",
                "sys_config": {"chips": 4}, "objective": 0.5}
               for i in range(2)]
    with open(p, "w") as f:
        json.dump(entries, f)
    gt = GroundTruth(path=p)
    assert len(gt.entries) == 2 and gt.kmeans is not None


# -------------------------------------------------------------- concurrency

def test_concurrent_clients_consistent_store_and_journal(tmp_path):
    path = str(tmp_path / "gt.jsonl")
    svc = GroundTruthService(path=path)
    client = StoreClient(InprocTransport(svc))
    n_threads, per_thread = 8, 8
    errors = []

    def worker(t):
        try:
            for i in range(per_thread):
                client.add(_profile(t * 100 + i, block=t % 4), f"w{t}",
                           {"chips": 4 + t}, 0.5)
                client.lookup(_profile(t * 100 + i + 1, block=t % 4))
        except Exception as e:                      # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert len(svc.store.entries) == n_threads * per_thread
    assert client.hits + client.misses == n_threads * per_thread
    svc.close()
    svc2 = GroundTruthService(path=path)            # journal stayed loadable
    assert len(svc2.store.entries) == n_threads * per_thread
    svc2.close()


# ------------------------------------------------------------------- socket

def test_socket_transport_roundtrip_ephemeral_port(tcp_server):
    svc = GroundTruthService()
    client = tcp_server(svc)
    for i in range(3):
        client.add(_profile(i), "w", {"chips": 4}, 0.8)
    score, cfg = client.lookup(_profile(31))
    assert cfg == {"chips": 4} and 0 < score <= 1
    assert len(svc.store.entries) == 3
    snap = client.snapshot()
    assert snap["n_entries"] == 3 and snap["version"] == svc.store.version


def test_socket_client_sees_other_clients_adds(tcp_server):
    svc = GroundTruthService()
    reader, writer = tcp_server(svc), StoreClient(InprocTransport(svc))
    assert reader.lookup(_profile(1))[1] is None    # cold store: miss
    for i in range(3):
        writer.add(_profile(i), "w", {"chips": 4}, 0.8)
    # default sync="piggyback": a purely-local reader only learns about
    # other writers' refits from the version piggybacked on its *next*
    # RPC of any kind — issue one, then the stale cache self-invalidates
    reader.version()
    score, cfg = reader.lookup(_profile(41))
    assert cfg == {"chips": 4} and score > 0


def test_ping_sync_client_sees_other_clients_adds_without_own_traffic():
    svc = GroundTruthService()
    reader = StoreClient(InprocTransport(svc), sync="ping")
    writer = StoreClient(InprocTransport(svc))
    assert reader.lookup(_profile(1))[1] is None
    for i in range(3):
        writer.add(_profile(i), "w", {"chips": 4}, 0.8)
    # legacy mode pings `version` on every lookup: the refit is visible
    # immediately, no reader-side RPC needed first
    score, cfg = reader.lookup(_profile(41))
    assert cfg == {"chips": 4} and score > 0


# ------------------------------------- acceptance: warm service over socket

def _pipetune_job(store, epochs=6, n_trials=4):
    pt = PipeTune(SimBackend(), SimSystemSpace(), groundtruth=store,
                  max_probes=4)
    res = pt.run_job(_job(epochs=epochs), scheduler="random",
                     n_trials=n_trials)
    return res


@pytest.mark.slow
def test_warm_socket_service_reproduces_inproc_run(tmp_path, tcp_server):
    """Acceptance: a PipeTune job against a warm GroundTruthService over
    SocketTransport reproduces the in-process run exactly — same gt_hit
    pattern, zero probe epochs on hits, same locked configs."""
    warm = str(tmp_path / "warm.jsonl")
    svc = GroundTruthService(path=warm)
    _pipetune_job(StoreClient(InprocTransport(svc)))   # cold warm-up run
    svc.close()

    copy_a, copy_b = str(tmp_path / "a.jsonl"), str(tmp_path / "b.jsonl")
    shutil.copy(warm, copy_a)
    shutil.copy(warm, copy_b)
    res_in = _pipetune_job(
        StoreClient(InprocTransport(GroundTruthService(path=copy_a))))
    res_tcp = _pipetune_job(tcp_server(GroundTruthService(path=copy_b)))

    assert sorted(res_in.records) == sorted(res_tcp.records)
    hits = 0
    for tid, rec_in in res_in.records.items():
        rec_tcp = res_tcp.records[tid]
        assert rec_in.gt_hit == rec_tcp.gt_hit, tid
        assert rec_in.probe_epochs == rec_tcp.probe_epochs, tid
        assert rec_in.sys_history == rec_tcp.sys_history, tid
        if rec_in.gt_hit:
            hits += 1
            assert rec_in.probe_epochs == 0
    assert hits > 0, "warm store produced no ground-truth hits"
    assert (res_in.gt_hits, res_in.gt_misses) == \
        (res_tcp.gt_hits, res_tcp.gt_misses)
    assert res_in.best_hparams == res_tcp.best_hparams
    assert res_in.best_score == res_tcp.best_score


# ----------------------------------------------------------- tagged engine

def test_engine_tagged_dispatch_respects_tags():
    cfg = ClusterConfig(n_nodes=3, node_tags=("a", "a", "b"), seed=0)
    eng = EventEngine(cfg)
    stats = [eng.submit(f"b{i}", iter([5.0]), tag="b") for i in range(3)]
    free = eng.submit("free", iter([5.0]))          # untagged: any node
    eng.run()
    assert all(s.node == 2 for s in stats)          # only node 2 carries "b"
    assert stats[1].start_s >= stats[0].finish_s    # queued behind shard-mate
    assert free.node in (0, 1)                      # took a free "a" node
    with pytest.raises(ValueError):
        eng.submit("x", iter([1.0]), tag="missing")


def test_cluster_config_rejects_mismatched_tags():
    with pytest.raises(ValueError):
        ClusterConfig(n_nodes=2, node_tags=("a",))


# ----------------------------------------------------------------- sharded

@pytest.mark.parametrize("tuner", ["v1", "pipetune"])
def test_sharded_single_backend_bit_identical_to_serial(tuner):
    """Acceptance: "sharded" with one backend == "serial", bit for bit,
    including PipeTune's ground-truth hit pattern."""
    def run(executor):
        exp = (Experiment(_job())
               .with_tuner(tuner, **({"max_probes": 4}
                                     if tuner == "pipetune" else {}))
               .with_backend("sim")
               .with_groundtruth(GroundTruth())
               .with_scheduler("hyperband"))
        return exp.run(executor=executor)

    serial = run(SerialTrialExecutor())
    sharded = run(ShardedTrialExecutor(backends=[("sim", SimBackend())],
                                       capacity=1))
    assert serial.best_hparams == sharded.best_hparams
    assert serial.best_score == sharded.best_score
    assert sorted(serial.records) == sorted(sharded.records)
    for tid, rec_s in serial.records.items():
        rec_x = sharded.records[tid]
        assert [e.accuracy for e in rec_s.epochs] == \
            [e.accuracy for e in rec_x.epochs], tid
        assert rec_s.sys_history == rec_x.sys_history, tid
        assert rec_s.gt_hit == rec_x.gt_hit, tid
        assert rec_s.probe_epochs == rec_x.probe_epochs, tid
    assert (serial.gt_hits, serial.gt_misses) == \
        (sharded.gt_hits, sharded.gt_misses)
    assert sharded.sim_time_s > 0


def test_sharded_registry_name_resolves_backends():
    res = (Experiment(_job(epochs=6))
           .with_tuner("v1").with_backend("sim")
           .with_scheduler("random", n_trials=4)
           .with_executor("sharded", backends=["sim", "sim"], capacity=1)
           .run())
    assert len(res.records) == 4 and res.sim_time_s > 0


def test_sharded_trials_stick_to_their_backend_across_rungs():
    executor = ShardedTrialExecutor(
        backends=[("s0", SimBackend()), ("s1", SimBackend())], capacity=1)
    res = (Experiment(_job())
           .with_tuner("v1").with_backend("sim")
           .with_scheduler("hyperband")
           .run(executor=executor))
    assert set(executor.shard_tags) == {"s0", "s1"}
    used = {d.backend for d in executor.history}
    assert used == {"s0", "s1"}                     # fan-out used both shards
    # a trial resumed across rungs must always dispatch to one shard, and
    # nodes must match that shard's tag
    by_trial = {}
    for d in executor.history:
        by_trial.setdefault(d.trial_id, set()).add(d.backend)
        assert executor.engine._tags[d.node] == d.backend
    assert all(len(tags) == 1 for tags in by_trial.values())
    resumed = [t for t in by_trial
               if sum(d.trial_id == t for d in executor.history) > 1]
    assert resumed, "hyperband should resume trials across rungs"
    assert len(res.records) > 0


def test_sharded_shares_groundtruth_service_across_backends(tmp_path):
    svc = GroundTruthService(path=str(tmp_path / "gt.jsonl"))
    client = StoreClient(InprocTransport(svc))
    res = (Experiment(_job(epochs=6))
           .with_tuner("pipetune", max_probes=4)
           .with_backend("sim")
           .with_groundtruth(client)
           .with_scheduler("random", n_trials=6)
           .run(executor=ShardedTrialExecutor(
               backends=[("s0", SimBackend()), ("s1", SimBackend())])))
    assert res.gt_hits + res.gt_misses == len(res.records)
    # probe results from trials on *both* shards landed in the one store
    assert len(svc.store.entries) >= 1
    assert res.gt_hits >= 1, "same-workload trials should hit the shared gt"


# ----------------------------------------------------------- metrics store

def test_metrics_store_context_manager_flushes_partial_batch(tmp_path):
    with MetricsStore(str(tmp_path)) as ms:
        for i in range(10):                          # < the 64-record buffer
            ms.write("epochs", {"i": i}, ts=float(i))
    path = tmp_path / "epochs.jsonl"
    assert path.exists()
    assert len(path.read_text().splitlines()) == 10


def test_metrics_store_finalizer_flushes_on_gc(tmp_path):
    ms = MetricsStore(str(tmp_path))
    ms.write("m", {"x": 1}, ts=0.0)
    del ms                                           # finalizer must flush
    import gc
    gc.collect()
    assert len((tmp_path / "m.jsonl").read_text().splitlines()) == 1


def test_metrics_store_query_still_sees_buffered_records(tmp_path):
    ms = MetricsStore(str(tmp_path))
    ms.write("m", {"x": 1}, tags={"k": "v"}, ts=1.0)
    assert len(ms.query("m", tags={"k": "v"})) == 1
    ms.close()


# ------------------------------------------------------------------ launch

def test_store_client_from_args_inproc_and_reset(tmp_path):
    import argparse
    from repro.launch.sysargs import add_store_args, store_client_from_args
    path = str(tmp_path / "gt.jsonl")
    ap = add_store_args(argparse.ArgumentParser())
    args = ap.parse_args(["--gt-store", path])
    client = store_client_from_args(args)
    client.add(_profile(0), "w", {"chips": 4}, 0.5)
    client.transport.service.close()
    # corrupt the journal head: plain relaunch fails loudly...
    with open(path, "w") as f:
        f.write("not json\n")
    with pytest.raises(GroundTruthError, match="--store-reset"):
        store_client_from_args(ap.parse_args(["--gt-store", path]))
    # ...and --store-reset is the documented escape hatch
    client = store_client_from_args(
        ap.parse_args(["--gt-store", path, "--store-reset"]))
    assert client.snapshot()["n_entries"] == 0

def test_store_client_from_args_rejects_bad_spec():
    import argparse
    from repro.launch.sysargs import add_store_args, store_client_from_args
    ap = add_store_args(argparse.ArgumentParser())
    with pytest.raises(ValueError):
        store_client_from_args(ap.parse_args(["--store", "udp://x"]))
    with pytest.raises(ValueError):
        store_client_from_args(ap.parse_args(["--store", "tcp://nohost"]))
    # --store-reset cannot reach a remote store: refuse instead of
    # silently ignoring the flag the corrupt-journal error recommended
    with pytest.raises(ValueError, match="in-proc"):
        store_client_from_args(ap.parse_args(
            ["--store", "tcp://127.0.0.1:7077", "--store-reset"]))
