"""sys.use_pallas routes attention through the flash kernel (interpret on
CPU) and must agree with the jnp path end-to-end."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer as T


@pytest.mark.parametrize("arch", ["yi-34b", "mixtral-8x22b"])
def test_use_pallas_matches_jnp_forward(arch):
    cfg = configs.get_reduced(arch)
    if cfg.family == "moe":
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = T.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    base_sys = T.SystemConfig(precision="fp32", q_chunk=16, kv_chunk=16)
    l1, _ = T.forward(params, {"tokens": toks}, cfg, base_sys)
    l2, _ = T.forward(params, {"tokens": toks}, cfg,
                      dataclasses.replace(base_sys, use_pallas=True))
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-4,
                               atol=1e-4)


def test_use_pallas_grads_finite():
    cfg = configs.get_reduced("yi-34b")
    params = T.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    sys = T.SystemConfig(precision="fp32", use_pallas=True, q_chunk=16,
                         kv_chunk=16)
    g = jax.grad(lambda p: T.loss_fn(p, {"tokens": toks, "labels": toks},
                                     cfg, sys)[0])(params)
    for leaf in jax.tree.leaves(g):
        assert jnp.isfinite(leaf).all()
