"""Event engine, cluster trial executor, AsyncASHA: the PR-2 acceptance
surface — executor parity with serial, the asynchrony win, determinism."""
import dataclasses

import numpy as np
import pytest

from repro.api import Experiment, available_executors, make_executor
from repro.cluster.engine import ClusterConfig, EventEngine
from repro.cluster.executor import ClusterTrialExecutor
from repro.cluster.sim import SIM_SYS_DEFAULT, SimBackend
from repro.core import TuneV1
from repro.core.job import HPTJob, Param, SearchSpace
from repro.core.schedulers import AsyncASHA, HyperBand


def _space():
    return SearchSpace([
        Param("batch_size", "choice", choices=(32, 64, 256, 1024)),
        Param("learning_rate", "log", 0.001, 0.1),
    ])


def _job(seed=0, epochs=9):
    return HPTJob(workload="lenet-mnist", space=_space(), max_epochs=epochs,
                  seed=seed)


# ------------------------------------------------------------------ engine

def test_engine_single_node_runs_tasks_fifo():
    eng = EventEngine(ClusterConfig(n_nodes=1, seed=0))
    stats = [eng.submit(f"t{i}", iter([10.0, 10.0]), at=float(i))
             for i in range(3)]
    eng.run()
    assert [s.task_id for s in eng.completed] == ["t0", "t1", "t2"]
    for a, b in zip(stats, stats[1:]):
        assert b.start_s >= a.finish_s
    assert stats[0].queue_s == 0.0
    assert stats[2].queue_s > 0.0               # waited behind t0, t1
    assert all(s.service_s == 20.0 and s.n_epochs == 2 for s in stats)


def test_engine_parallel_nodes_overlap():
    eng = EventEngine(ClusterConfig(n_nodes=2, seed=0))
    a = eng.submit("a", iter([30.0]), at=0.0)
    b = eng.submit("b", iter([30.0]), at=0.0)
    eng.run()
    assert a.start_s == b.start_s == 0.0        # both dispatched immediately
    assert eng.now == 30.0


def test_engine_fault_injection_is_deterministic():
    def run_once():
        eng = EventEngine(ClusterConfig(n_nodes=2, straggler_prob=0.3,
                                        mtbf_s=200.0, seed=7))
        stats = [eng.submit(f"t{i}", iter([50.0] * 6)) for i in range(4)]
        eng.run()
        return [dataclasses.asdict(s) for s in stats]

    r1, r2 = run_once(), run_once()
    assert r1 == r2
    assert sum(s["n_stragglers"] + s["n_failures"] for s in r1) > 0
    assert any(s["service_s"] > 300.0 for s in r1)   # faults cost time


def test_engine_run_next_completion_orders_by_clock():
    eng = EventEngine(ClusterConfig(n_nodes=2, seed=0))
    eng.submit("slow", iter([100.0]))
    eng.submit("fast", iter([10.0]))
    first = eng.run_next_completion()
    assert first.task_id == "fast" and eng.now == 10.0
    second = eng.run_next_completion()
    assert second.task_id == "slow" and eng.now == 100.0
    assert eng.run_next_completion() is None


# ---------------------------------------------------------------- executor

@pytest.mark.parametrize("scheduler", ["hyperband", "random"])
def test_cluster_executor_matches_serial_without_faults(scheduler):
    """Acceptance: faults off, one job -> wave scores bit-identical to the
    serial executor on the deterministic SimBackend (the engine only ever
    perturbs *time*)."""
    kw = {"n_trials": 8} if scheduler == "random" else {}
    serial = (Experiment(_job()).with_tuner("v1").with_backend("sim")
              .with_scheduler(scheduler, **kw).run())
    ex = ClusterTrialExecutor(cluster=ClusterConfig(n_nodes=4, seed=0),
                              default_sys=SIM_SYS_DEFAULT)
    cluster = (Experiment(_job()).with_tuner("v1").with_backend("sim")
               .with_scheduler(scheduler, **kw).run(executor=ex))
    assert serial.best_hparams == cluster.best_hparams
    assert serial.best_score == cluster.best_score
    assert sorted(serial.records) == sorted(cluster.records)
    for tid in serial.records:
        assert [e.accuracy for e in serial.records[tid].epochs] == \
            [e.accuracy for e in cluster.records[tid].epochs], tid
    assert cluster.sim_time_s > 0.0


def test_cluster_executor_dispatch_history_and_queueing():
    ex = ClusterTrialExecutor(cluster=ClusterConfig(n_nodes=2, seed=0),
                              default_sys=SIM_SYS_DEFAULT)
    res = (Experiment(_job()).with_tuner("v1").with_backend("sim")
           .with_scheduler("random", n_trials=6).run(executor=ex))
    assert len(res.records) == 6
    assert len(ex.history) == 6
    # 6 trials on 2 nodes: somebody queued behind a wave-mate
    assert any(h.queue_s > 0 for h in ex.history)
    assert all(h.finish_s > h.start_s for h in ex.history)
    assert {h.node for h in ex.history} == {0, 1}
    assert res.sim_time_s == pytest.approx(max(h.finish_s
                                               for h in ex.history))


def test_cluster_executor_is_registered():
    assert {"serial", "parallel", "cluster"} <= set(available_executors())
    assert isinstance(make_executor("cluster", n_nodes=2),
                      ClusterTrialExecutor)
    assert make_executor(1).parallelism == 1    # int compatibility
    with pytest.raises(KeyError, match=r"unknown executor 'gpu'.*available"):
        make_executor("gpu")


def test_experiment_with_executor_by_name():
    res = (Experiment(_job()).with_tuner("v1").with_backend("sim")
           .with_scheduler("random", n_trials=4)
           .with_executor("cluster", n_nodes=2).run())
    assert len(res.records) == 4
    assert res.sim_time_s > 0.0


# --------------------------------------------------------------- AsyncASHA

def test_async_asha_protocol_rung_parallel_waves():
    sched = AsyncASHA(_space(), max_epochs=9, eta=3, n_trials=9, seed=0)
    wave = sched.suggest()
    assert len(wave) == 9                       # rung-parallel, not 1-by-1
    assert len({p.trial_id for p in wave}) == 9
    assert all(p.epochs == 1 for p in wave)
    # reporting mid-wave releases promotions without waiting for wave-mates
    sched.report(wave[0].trial_id, 0.9)
    sched.report(wave[1].trial_id, 0.5)
    sched.report(wave[2].trial_id, 0.1)
    promo = sched.suggest()
    assert [p.trial_id for p in promo] == [wave[0].trial_id]
    assert promo[0].epochs == 3
    for p in wave[3:]:
        sched.report(p.trial_id, 0.0)
    sched.report(promo[0].trial_id, 0.95)
    while not sched.done:
        nxt = sched.suggest()
        assert nxt, "scheduler stuck: not done but no proposals"
        for p in nxt:
            sched.report(p.trial_id, 0.99)
    best_hp, best_score = sched.best()
    assert best_score == 0.99 and best_hp is not None


def test_async_asha_runs_serially_via_legacy_shim():
    sched = AsyncASHA(_space(), max_epochs=9, eta=3, n_trials=9, seed=3)
    hp, score = sched.run(lambda tid, hp, ep: hp["learning_rate"] * ep)
    assert sched.done
    assert score > 0 and hp is not None


def _final_rung_stats(scheduler, seed):
    ex = ClusterTrialExecutor(
        cluster=ClusterConfig(n_nodes=4, straggler_prob=0.3, seed=seed),
        default_sys=SIM_SYS_DEFAULT)
    res = (Experiment(_job(seed=seed)).with_tuner("v1").with_backend("sim")
           .with_scheduler(scheduler, **({"n_trials": 9}
                                         if scheduler == "asha-async"
                                         else {})).run(executor=ex))
    final = [h.finish_s for h in ex.history if h.epochs == 9]
    assert final, f"{scheduler} never dispatched a final-rung trial"
    return min(final), res.sim_time_s, res


@pytest.mark.parametrize("seed", [0, 1])
def test_async_asha_beats_barrier_hyperband_under_stragglers(seed):
    """Acceptance: with stragglers, AsyncASHA on the event engine reaches
    its final rung in strictly less simulated time than rung-synchronized
    HyperBand on the same seed — the promotions overlap the stragglers the
    barrier has to wait out."""
    t_asha, makespan_asha, _ = _final_rung_stats("asha-async", seed)
    t_hb, makespan_hb, _ = _final_rung_stats("hyperband", seed)
    assert t_asha < t_hb
    assert makespan_asha < makespan_hb


def test_async_asha_event_decisions_differ_only_in_timing():
    """Acceptance: versus the fault-free serial drive, the event engine
    changes *when* AsyncASHA hears scores (hence which promotions fire),
    never the scores themselves — SimBackend epochs are pure functions of
    (trial, epoch), so any (trial, rung) evaluated by both paths must agree
    bit-for-bit."""
    job = _job(seed=0)
    serial = (Experiment(job).with_tuner("v1").with_backend("sim")
              .with_scheduler("asha-async", n_trials=9).run())
    ex = ClusterTrialExecutor(
        cluster=ClusterConfig(n_nodes=4, straggler_prob=0.4, seed=0),
        default_sys=SIM_SYS_DEFAULT)
    event = (Experiment(job).with_tuner("v1").with_backend("sim")
             .with_scheduler("asha-async", n_trials=9).run(executor=ex))
    common = set(serial.records) & set(event.records)
    assert common                               # same initial rung at least
    for tid in common:
        s_acc = [e.accuracy for e in serial.records[tid].epochs]
        e_acc = [e.accuracy for e in event.records[tid].epochs]
        k = min(len(s_acc), len(e_acc))         # shared rung prefix
        assert s_acc[:k] == e_acc[:k], tid
