"""shard_map compressed gradient reduction (multi-device via subprocess)."""
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.collectives import compressed_grad_mean
mesh = jax.make_mesh((8,), ("data",))
rng = np.random.RandomState(0)
grads = {"w": jnp.asarray(rng.randn(8, 64, 32), jnp.float32),
         "b": jnp.asarray(rng.randn(8, 32), jnp.float32)}
exact = jax.tree.map(lambda g: g.mean(0), grads)
for method in ("none", "int8"):
    out = compressed_grad_mean(grads, mesh, method=method)
    for k in grads:
        err = float(jnp.max(jnp.abs(out[k] - exact[k])))
        scale = float(jnp.max(jnp.abs(exact[k]))) + 1e-9
        tol = 1e-6 if method == "none" else 0.05 * scale + 0.05
        assert err < tol, (method, k, err, tol)
        assert out[k].shape == exact[k].shape
print("COLLECTIVES_OK")
"""


def test_compressed_grad_mean_multidevice():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=300,
                       env={**__import__("os").environ,
                            "PYTHONPATH": "src"})
    assert "COLLECTIVES_OK" in r.stdout, r.stdout + r.stderr


def test_compressed_psum_single_device():
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.distributed.collectives import compressed_grad_mean
    mesh = jax.make_mesh((1,), ("data",))
    g = {"w": jnp.asarray(np.random.RandomState(1).randn(1, 16), jnp.float32)}
    out = compressed_grad_mean(g, mesh, method="int8")
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"][0]),
                               rtol=2e-2, atol=2e-2)
