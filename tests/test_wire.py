"""Binary wire protocol + batched dispatch (PR 7 acceptance surface):
codec round-trips (bit-exact floats, fuzzed nested payloads), framing
edge cases (torn frames, interleaved partial sends, oversized frames),
per-connection codec negotiation, the batched store/worker ops, and the
perf-path invariant — remote == in-process bit-identical under every
codec and under batched dispatch, including a mid-batch connection drop.
"""
import math
import random
import socket
import struct
import threading
import time

import numpy as np
import pytest

from repro.api import Experiment, RemoteWorker, WorkerPoolExecutor
from repro.core.groundtruth import GroundTruth
from repro.core.job import HPTJob, Param, SearchSpace
from repro.service import (DropConnection, GroundTruthService,
                           GroundTruthTCPServer, InprocTransport,
                           JsonRPCServer, SocketTransport, StoreClient,
                           StoreError, TransportError, TrialWorkerService,
                           available_codecs, get_codec, serve_worker)
from repro.service.codec import CodecError, best_binary_codec
from repro.service.transport import (MAX_FRAME_BYTES, _recv_frame, _recv_msg,
                                     _send_msg)

BINARY = best_binary_codec().name


# ---------------------------------------------------------------- codecs

def _float_bits(x):
    return struct.pack(">d", x)


def _assert_same(a, b, path="$"):
    """Structural equality with float *bit* equality (nan == nan)."""
    assert type(a) is type(b) or (isinstance(a, (list, tuple)) and
                                  isinstance(b, (list, tuple))), \
        f"{path}: {type(a).__name__} != {type(b).__name__}"
    if isinstance(a, float):
        assert _float_bits(a) == _float_bits(b), f"{path}: {a!r} != {b!r}"
    elif isinstance(a, dict):
        assert sorted(a) == sorted(b), path
        for k in a:
            _assert_same(a[k], b[k], f"{path}.{k}")
    elif isinstance(a, (list, tuple)):
        assert len(a) == len(b), path
        for i, (x, y) in enumerate(zip(a, b)):
            _assert_same(x, y, f"{path}[{i}]")
    else:
        assert a == b, f"{path}: {a!r} != {b!r}"


def _random_value(rng, depth=0):
    kinds = ["none", "bool", "int", "float", "str"]
    if depth < 3:
        kinds += ["list", "dict"] * 2
    kind = rng.choice(kinds)
    if kind == "none":
        return None
    if kind == "bool":
        return rng.random() < 0.5
    if kind == "int":
        # int64 range: the msgpack data model's integer bound
        return rng.randint(-(1 << 62), 1 << 62)
    if kind == "float":
        return rng.choice([
            rng.uniform(-1e300, 1e300), -0.0, 0.0, math.inf, -math.inf,
            math.nan, 1e-323, 0.1 + 0.2])
    if kind == "str":
        return "".join(rng.choice("abc λμ 🔥 \n\"\\0") for _ in
                       range(rng.randint(0, 12)))
    if kind == "list":
        return [_random_value(rng, depth + 1)
                for _ in range(rng.randint(0, 5))]
    return {f"k{i}-{rng.randint(0, 99)}": _random_value(rng, depth + 1)
            for i in range(rng.randint(0, 5))}


@pytest.mark.parametrize("name", list(available_codecs()))
def test_codec_fuzz_round_trip_bit_exact(name):
    """decode(encode(x)) == x with float bits preserved, for randomly
    nested payloads, on every codec this process can speak."""
    codec = get_codec(name)
    rng = random.Random(1234)
    for i in range(200):
        payload = {"op": "fuzz", "v": _random_value(rng)}
        _assert_same(payload, codec.decode(codec.encode(payload)), f"#{i}")


@pytest.mark.parametrize("name", list(available_codecs()))
def test_codec_special_floats_bit_exact(name):
    codec = get_codec(name)
    vals = [math.nan, math.inf, -math.inf, -0.0, 0.0, 5e-324,
            1.7976931348623157e308, 0.1, 1 / 3]
    out = codec.decode(codec.encode({"v": vals}))["v"]
    assert [_float_bits(x) for x in vals] == [_float_bits(y) for y in out]


def test_codecs_agree_across_the_matrix():
    """The same payload survives any encode/decode pair of codecs — the
    encoding is never a semantics choice."""
    rng = random.Random(7)
    payloads = [{"op": "x", "v": _random_value(rng)} for _ in range(50)]
    codecs = [get_codec(n) for n in available_codecs()]
    for p in payloads:
        decoded = [c.decode(c.encode(p)) for c in codecs]
        for d in decoded[1:]:
            _assert_same(decoded[0], d)


def test_tlv_bigint_bytes_and_errors():
    tlv = get_codec("tlv")
    big = 17 ** 40
    assert tlv.decode(tlv.encode({"n": big, "m": -big})) == \
        {"n": big, "m": -big}
    assert tlv.decode(tlv.encode({"b": b"\x00\xffraw"}))["b"] == b"\x00\xffraw"
    with pytest.raises(CodecError, match="keys must be str"):
        tlv.encode({1: "x"})
    with pytest.raises(CodecError, match="cannot encode"):
        tlv.encode({"x": object()})
    with pytest.raises(CodecError, match="truncated"):
        tlv.decode(tlv.encode({"a": [1, 2, 3]})[:-4])
    with pytest.raises(CodecError, match="trailing"):
        tlv.decode(tlv.encode({"a": 1}) + b"\x00")
    with pytest.raises(CodecError, match="unknown tlv tag"):
        tlv.decode(b"\xc1")


def test_get_codec_binary_alias_and_unknown():
    assert get_codec("binary").name == BINARY
    with pytest.raises(CodecError, match="unknown wire codec"):
        get_codec("protobuf")


# ------------------------------------------------------- framing edge cases

@pytest.fixture
def store_server():
    svc = GroundTruthService()
    server = GroundTruthTCPServer(("127.0.0.1", 0), svc)
    threading.Thread(target=server.serve_forever, daemon=True).start()
    yield server
    server.shutdown()


def test_torn_frames_reassemble(store_server):
    """A request trickling in byte by byte (worst-case TCP segmentation)
    must reassemble into one frame and get a normal response."""
    sock = socket.create_connection(store_server.server_address[:2],
                                    timeout=10)
    payload = get_codec("json").encode({"op": "version"})
    frame = struct.pack(">I", len(payload)) + payload
    for i in range(len(frame)):
        sock.sendall(frame[i:i + 1])
        if i % 7 == 0:
            time.sleep(0.001)
    resp = _recv_msg(sock)
    assert resp["ok"] and resp["version"] == 0
    sock.close()


def test_interleaved_partial_sends_stay_isolated(store_server):
    """Two connections sending halves of their frames alternately: the
    selector loop buffers per connection, so neither sees the other's
    bytes and both get correct responses."""
    addr = store_server.server_address[:2]
    socks = [socket.create_connection(addr, timeout=10) for _ in range(2)]
    frames = []
    for i in range(2):
        payload = get_codec("json").encode(
            {"op": "add", "profile": [float(i)] * 3, "workload": f"wl{i}",
             "sys_config": {"chips": i}, "objective": 0.5})
        frames.append(struct.pack(">I", len(payload)) + payload)
    cut = [len(f) // 2 for f in frames]
    for s, f, c in zip(socks, frames, cut):      # first halves, interleaved
        s.sendall(f[:c])
    time.sleep(0.05)
    for s, f, c in zip(socks, frames, cut):      # then the second halves
        s.sendall(f[c:])
    versions = []
    for i, s in enumerate(socks):
        resp = _recv_msg(s)
        assert resp["ok"], resp
        versions.append(resp["version"])
    # the two adds ran on concurrent handler threads, so either may have
    # answered first — but both landed, each with its own version bump
    assert sorted(versions) == [1, 2]
    payload = get_codec("json").encode({"op": "snapshot"})
    socks[0].sendall(struct.pack(">I", len(payload)) + payload)
    snap = _recv_msg(socks[0])
    assert snap["ok"] and snap["n_entries"] == 2  # both adds landed
    for s in socks:
        s.close()


def test_oversized_frame_raises_naming_the_peer():
    a, b = socket.socketpair()
    a.settimeout(5)
    b.sendall(struct.pack(">I", MAX_FRAME_BYTES + 1))
    with pytest.raises(TransportError, match="exceeds the .*-byte cap"):
        _recv_frame(a, peer="10.1.2.3:7077")
    b.sendall(struct.pack(">I", 2048))           # over a tighter custom cap
    try:
        _recv_frame(a, max_frame=1024, peer="10.1.2.3:7077")
    except TransportError as e:
        assert "10.1.2.3:7077" in str(e)
    else:
        pytest.fail("oversized frame accepted")
    a.close()
    b.close()


def test_server_closes_connection_on_oversized_frame(store_server):
    """A corrupt length prefix (or a non-repro peer) must not make the
    server allocate gigabytes — it drops the connection instead."""
    sock = socket.create_connection(store_server.server_address[:2],
                                    timeout=10)
    sock.sendall(struct.pack(">I", 0xFFFFFFFF) + b"junk")
    sock.settimeout(5)
    assert sock.recv(1) == b""                   # orderly close, no reply
    sock.close()
    # the server survives for well-formed clients
    with StoreClient(SocketTransport(*store_server.server_address[:2])) as c:
        assert c.version() == 0


def test_server_closes_connection_on_undecodable_frame(store_server):
    sock = socket.create_connection(store_server.server_address[:2],
                                    timeout=10)
    sock.sendall(struct.pack(">I", 4) + b"\x00ah!")
    sock.settimeout(5)
    assert sock.recv(1) == b""
    sock.close()
    # only the offending connection died — the serve loop is still up
    # (a decode error must never escape and kill the I/O thread)
    with StoreClient(SocketTransport(*store_server.server_address[:2])) as c:
        assert c.version() == 0


# ------------------------------------------------------------- negotiation

def _legacy_json_server(n_requests=4):
    """A pre-codec peer: speaks only JSON framing and errors unknown ops
    (which is how a real legacy server answers the ``_wire`` hello)."""
    listener = socket.socket()
    listener.bind(("127.0.0.1", 0))
    listener.listen(4)

    def serve():
        conn, _ = listener.accept()
        try:
            for _ in range(n_requests):
                req = _recv_msg(conn)
                if req.get("op") == "version":
                    _send_msg(conn, {"ok": True, "version": 0})
                else:
                    _send_msg(conn, {"ok": False,
                                     "error": f"unknown op {req.get('op')!r}"})
        except (ConnectionError, OSError):
            pass
        conn.close()

    threading.Thread(target=serve, daemon=True).start()
    return listener, listener.getsockname()[1]


def test_auto_negotiates_binary_against_new_server(store_server):
    t = SocketTransport(*store_server.server_address[:2], wire="auto")
    assert t.codec_name == BINARY
    assert t.request({"op": "version"})["ok"]
    t.close()


def test_forced_json_skips_the_hello(store_server):
    t = SocketTransport(*store_server.server_address[:2], wire="json")
    assert t.codec_name == "json"
    assert t.request({"op": "version"})["ok"]
    t.close()


def test_forced_tlv_works_against_new_server(store_server):
    t = SocketTransport(*store_server.server_address[:2], wire="tlv")
    assert t.codec_name == "tlv"
    client = StoreClient(t)
    client.add(np.ones(3), "wl", {"chips": 2}, 0.9)
    assert client.version() == 1
    client.close()


def test_auto_falls_back_to_json_on_legacy_peer():
    listener, port = _legacy_json_server()
    t = SocketTransport("127.0.0.1", port, wire="auto")
    assert t.codec_name == "json"                # declined hello, no error
    assert t.request({"op": "version"}) == {"ok": True, "version": 0}
    t.close()
    listener.close()


def test_forced_binary_against_legacy_peer_is_a_clear_error():
    listener, port = _legacy_json_server(n_requests=1)
    with pytest.raises(TransportError, match="declined wire codec"):
        SocketTransport("127.0.0.1", port, wire=BINARY)
    listener.close()


def test_generic_ok_responder_does_not_flip_the_wire():
    """A service that answers unknown ops with a bare {"ok": true} must
    not be mistaken for codec support: the hello requires the codec name
    echoed back, or the connection stays on JSON."""
    listener = socket.socket()
    listener.bind(("127.0.0.1", 0))
    listener.listen(1)

    def serve():
        conn, _ = listener.accept()
        try:
            while True:
                _recv_msg(conn)
                _send_msg(conn, {"ok": True})    # no "codec" echo
        except (ConnectionError, OSError):
            pass
        conn.close()

    threading.Thread(target=serve, daemon=True).start()
    t = SocketTransport("127.0.0.1", listener.getsockname()[1], wire="auto")
    assert t.codec_name == "json"
    assert t.request({"op": "anything"})["ok"]   # still JSON-intelligible
    t.close()
    listener.close()


# ----------------------------------------------- batched store ops + journal

class _FlushCounter:
    def __init__(self, f):
        self.f, self.writes, self.flushes = f, 0, 0

    def write(self, s):
        self.writes += 1
        return self.f.write(s)

    def flush(self):
        self.flushes += 1
        return self.f.flush()

    def close(self):
        return self.f.close()


def _add_req(i, refit=False):
    return {"op": "add", "profile": [float(i), 1.0, 2.0],
            "workload": f"wl{i % 2}", "sys_config": {"chips": i},
            "objective": 0.5 + i / 100, "refit": refit}


def test_batch_op_pipelines_journal_to_one_flush(tmp_path):
    svc = GroundTruthService(path=str(tmp_path / "gt.jsonl"))
    svc._journal = counter = _FlushCounter(svc._journal)
    resp = svc.handle({"op": "batch",
                       "requests": [_add_req(i) for i in range(10)] +
                       [{"op": "refit"}]})
    assert resp["ok"] and len(resp["results"]) == 11
    assert all(sub["ok"] for sub in resp["results"])
    assert resp["results"][-1]["version"] == resp["version"] == 1
    assert (counter.writes, counter.flushes) == (1, 1)   # pipelined
    # scalar adds pay one write+flush each — the baseline the batch beats
    svc.handle(_add_req(99, refit=True))
    assert (counter.writes, counter.flushes) == (2, 2)
    svc.close()
    # write-ahead lines were real: a fresh service replays all 11 adds
    svc2 = GroundTruthService(path=str(tmp_path / "gt.jsonl"))
    assert len(svc2.store.entries) == 11
    svc2.close()


def test_batch_op_reports_bad_subrequests_in_place():
    svc = GroundTruthService()
    resp = svc.handle({"op": "batch", "requests": [
        _add_req(0), {"op": "nope"}, {"op": "batch", "requests": []},
        _add_req(1, refit=True)]})
    assert resp["ok"]
    oks = [sub.get("ok") for sub in resp["results"]]
    assert oks == [True, False, False, True]     # failures don't abort
    assert "unknown batch sub-op" in resp["results"][1]["error"]
    assert len(svc.store.entries) == 2
    svc.close()


def test_batch_requires_a_request_list():
    svc = GroundTruthService()
    assert not svc.handle({"op": "batch"})["ok"]
    assert not svc.handle({"op": "batch", "requests": "nope"})["ok"]


def test_evaluate_many_is_bit_identical_to_evaluate():
    gt = GroundTruth()
    rng = np.random.RandomState(3)
    for i in range(12):
        base = np.zeros(8)
        base[i % 3] = 10.0 * (1 + i % 3)
        gt.add(base + rng.randn(8) * 0.1, f"wl{i % 3}",
               {"chips": i % 3}, 0.8)
    model = gt.centroid_model()
    probes = [rng.randn(8) * 5 for _ in range(40)]
    scalar = [model.evaluate(p) for p in probes]
    batched = model.evaluate_many(probes)
    for (s0, c0), (s1, c1) in zip(scalar, batched):
        assert _float_bits(s0) == _float_bits(s1)
        assert c0 == c1


class _CountingTransport:
    def __init__(self, inner):
        self.inner, self.n_requests = inner, 0

    def request(self, req):
        self.n_requests += 1
        return self.inner.request(req)

    def close(self):
        self.inner.close()


def test_piggyback_lookup_is_rpc_free_when_warm():
    svc = GroundTruthService()
    transport = _CountingTransport(InprocTransport(svc))
    client = StoreClient(transport)
    client.add(np.ones(4), "wl", {"chips": 2}, 0.9)   # piggybacks version 1
    client.lookup(np.ones(4))                         # fetches the model
    warm = transport.n_requests
    results = [client.lookup(np.ones(4) + i * 1e-3) for i in range(50)]
    assert transport.n_requests == warm               # zero RPCs, all local
    assert all(cfg == {"chips": 2} for _, cfg in results)
    # a refit by another writer is seen at this client's next RPC
    other = StoreClient(InprocTransport(svc))
    other.add(np.ones(4) * 100, "wl2", {"chips": 8}, 0.9)
    client.version()                                  # any RPC re-syncs
    client.lookup(np.ones(4))
    assert client._model_version == 2
    client.close()
    other.close()


def test_lookup_many_matches_scalar_lookups_and_counts():
    svc = GroundTruthService()
    seed_client = StoreClient(InprocTransport(svc))
    rng = np.random.RandomState(11)
    for i in range(8):
        base = np.zeros(6)
        base[i % 2] = 25.0
        seed_client.add(base + rng.randn(6) * 0.05, f"wl{i % 2}",
                        {"chips": 2 + i % 2}, 0.85)
    probes = [rng.randn(6) * (0.1 if i % 2 else 30.0) for i in range(30)]
    a, b = (StoreClient(InprocTransport(svc)) for _ in range(2))
    scalar = [a.lookup(p) for p in probes]
    batched = b.lookup_many(probes)
    for (s0, c0), (s1, c1) in zip(scalar, batched):
        assert _float_bits(s0) == _float_bits(s1) and c0 == c1
    assert (a.hits, a.misses) == (b.hits, b.misses)
    assert b.lookup_many([]) == []
    a.close()
    b.close()


def test_add_many_is_one_round_trip():
    svc = GroundTruthService()
    transport = _CountingTransport(InprocTransport(svc))
    client = StoreClient(transport)
    rng = np.random.RandomState(5)
    version = client.add_many(
        [(rng.randn(4), f"wl{i}", {"chips": i}, 0.7) for i in range(6)])
    assert transport.n_requests == 1
    assert version == 1 and svc.store.version == 1    # single trailing refit
    assert len(svc.store.entries) == 6
    client.close()


# ----------------------------------- remote == in-process, codec + batching

def _space():
    return SearchSpace([
        Param("batch_size", "choice", choices=(32, 64, 256, 1024)),
        Param("learning_rate", "log", 0.001, 0.1),
    ])


def _job(seed=0, epochs=9):
    return HPTJob(workload="lenet-mnist", space=_space(), max_epochs=epochs,
                  seed=seed)


def _assert_bit_identical(a, b):
    assert a.best_hparams == b.best_hparams
    assert a.best_score == b.best_score
    assert sorted(a.records) == sorted(b.records)
    for tid, rec_a in a.records.items():
        rec_b = b.records[tid]
        assert [e.accuracy for e in rec_a.epochs] == \
            [e.accuracy for e in rec_b.epochs], tid
        assert [e.duration_s for e in rec_a.epochs] == \
            [e.duration_s for e in rec_b.epochs], tid
        assert rec_a.sys_history == rec_b.sys_history, tid


class _CountingService(TrialWorkerService):
    def __init__(self, **kw):
        super().__init__(**kw)
        self.ops = []

    def handle(self, req):
        self.ops.append(req.get("op"))
        return super().handle(req)


@pytest.fixture
def worker_server():
    made = []

    def make(service=None):
        server = serve_worker(service or TrialWorkerService(), port=0,
                              background=True)
        made.append(server)
        return server.server_address[1]

    yield make
    for server in made:
        server.shutdown()
        server.service.close()


@pytest.mark.parametrize("wire", ["json", "binary"])
def test_remote_run_bit_identical_under_both_codecs(worker_server, wire):
    """Acceptance: the negotiated binary codec changes the bytes on the
    wire and nothing else — remote == in-process bit for bit under JSON
    and binary alike."""
    port = worker_server()
    serial = (Experiment(_job()).with_tuner("v1").with_backend("sim")
              .with_scheduler("hyperband").run())
    worker = RemoteWorker(f"tcp://127.0.0.1:{port}", wire=wire)
    want = "json" if wire == "json" else BINARY
    assert worker.transport.codec_name == want
    ex = WorkerPoolExecutor([worker])
    remote = (Experiment(_job()).with_tuner("v1").with_backend("sim")
              .with_scheduler("hyperband").run(executor=ex))
    ex.close()
    _assert_bit_identical(serial, remote)


def test_batched_dispatch_uses_run_many_and_stays_bit_identical(
        worker_server):
    services = [_CountingService(), _CountingService()]
    ports = [worker_server(s) for s in services]
    ex = WorkerPoolExecutor([RemoteWorker(f"tcp://127.0.0.1:{p}")
                             for p in ports])
    serial = (Experiment(_job()).with_tuner("v1").with_backend("sim")
              .with_scheduler("random", n_trials=6).run())
    remote = (Experiment(_job()).with_tuner("v1").with_backend("sim")
              .with_scheduler("random", n_trials=6).run(executor=ex))
    ex.close()
    _assert_bit_identical(serial, remote)
    # the wave really was batched: one run_many per worker, no scalar runs
    for s in services:
        assert "run_many" in s.ops and "run" not in s.ops


def test_legacy_worker_without_run_many_falls_back_per_trial(worker_server):
    class _OldService(_CountingService):
        def handle(self, req):
            if req.get("op") == "run_many":
                self.ops.append("run_many")
                return {"ok": False, "error": "unknown op 'run_many'"}
            return super().handle(req)

    svc = _OldService()
    port = worker_server(svc)
    ex = WorkerPoolExecutor([RemoteWorker(f"tcp://127.0.0.1:{port}")])
    serial = (Experiment(_job()).with_tuner("v1").with_backend("sim")
              .with_scheduler("random", n_trials=4).run())
    remote = (Experiment(_job()).with_tuner("v1").with_backend("sim")
              .with_scheduler("random", n_trials=4).run(executor=ex))
    assert not ex.workers[0]._batched_runs       # remembered the decline
    ex.close()
    _assert_bit_identical(serial, remote)
    assert svc.ops.count("run_many") == 1        # asked once, never again
    assert svc.ops.count("run") == 4


def test_mid_batch_connection_drop_loses_no_trial_and_double_runs_none(
        worker_server):
    """Acceptance (+ chaos satellite core): a worker whose connection dies
    mid-``run_many`` reports every batch member as worker-lost; the pool
    retires it once and re-places the whole batch on the survivor. No
    trial is lost, none runs twice into the merged result, and the run is
    bit-identical to serial."""
    class _DropOnce(_CountingService):
        def handle(self, req):
            if req.get("op") == "run_many" and "run_many" not in self.ops:
                self.ops.append("run_many")
                raise DropConnection("chaos: mid-batch drop")
            return super().handle(req)

    dropping, survivor = _DropOnce(), _CountingService()
    ports = [worker_server(dropping), worker_server(survivor)]
    ex = WorkerPoolExecutor([RemoteWorker(f"tcp://127.0.0.1:{p}")
                             for p in ports])
    ex.pool.retire_on_error = True
    serial = (Experiment(_job()).with_tuner("v1").with_backend("sim")
              .with_scheduler("random", n_trials=6).run())
    remote = (Experiment(_job()).with_tuner("v1").with_backend("sim")
              .with_scheduler("random", n_trials=6).run(executor=ex))
    assert len(ex.pool.workers) == 1             # the dropper was retired
    ex.close()
    _assert_bit_identical(serial, remote)
    assert len(remote.records) == 6
    # every trial ran exactly once into the merged result: the survivor
    # picked up the dropped batch, and the dropper contributed nothing
    assert len(survivor.runner.records) == 6
    assert survivor.ops.count("run_many") >= 1


def test_store_client_over_every_codec_agrees_bit_for_bit(store_server):
    """Warm-socket == in-process across json / binary / tlv: the PR 3
    acceptance property, re-asserted per codec."""
    host, port = store_server.server_address[:2]
    svc = store_server.service
    rng = np.random.RandomState(9)
    seed_client = StoreClient(SocketTransport(host, port))
    for i in range(6):
        base = np.zeros(5)
        base[i % 2] = 15.0
        seed_client.add(base + rng.randn(5) * 0.1, f"wl{i % 2}",
                        {"chips": 1 + i % 2}, 0.8)
    seed_client.close()
    probes = [rng.randn(5) * (0.2 if i % 3 else 20.0) for i in range(25)]
    local = [StoreClient(InprocTransport(svc)).lookup(p) for p in probes]
    for wire in ["json", "binary", "tlv"]:
        client = StoreClient(SocketTransport(host, port, wire=wire))
        got = [client.lookup(p) for p in probes]
        for (s0, c0), (s1, c1) in zip(local, got):
            assert _float_bits(s0) == _float_bits(s1) and c0 == c1, wire
        batched = client.lookup_many(probes)
        for (s0, c0), (s1, c1) in zip(local, batched):
            assert _float_bits(s0) == _float_bits(s1) and c0 == c1, wire
        client.close()


def test_server_batch_op_over_the_socket(store_server):
    host, port = store_server.server_address[:2]
    with StoreClient(SocketTransport(host, port, wire="auto")) as client:
        version = client.add_many(
            [(np.full(4, float(i)), f"wl{i % 2}", {"chips": i}, 0.6)
             for i in range(5)])
        assert version == 1
        snap = client.snapshot()
        assert snap["n_entries"] == 5
