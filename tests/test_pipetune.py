"""Algorithm 1 behavior + baseline comparisons (fast, on SimBackend)."""
import numpy as np
import pytest

from repro.cluster.sim import (SIM_SYS_DEFAULT, SimBackend, SimSystemSpace)
from repro.core import GroundTruth, PipeTune, TuneV1, TuneV2
from repro.core.job import HPTJob, Param, SearchSpace


def _space():
    return SearchSpace([
        Param("batch_size", "choice", choices=(32, 64, 256, 1024)),
        Param("learning_rate", "log", 0.001, 0.1),
    ])


def _pipetune(gt=None, **kw):
    return PipeTune(SimBackend(), SimSystemSpace(), groundtruth=gt,
                    max_probes=4, **kw)


def test_trial_probes_then_locks():
    pt = _pipetune()
    rec = pt.run_trial("lenet-mnist", "t0",
                       {"batch_size": 64, "learning_rate": 0.01}, 9)
    # epoch 0 = default profile epoch; epochs 1..4 probe; rest locked
    assert rec.probe_epochs == 4
    locked = pt._locked["t0"]
    tail = rec.sys_history[1 + rec.probe_epochs:]
    assert all(s == locked for s in tail)
    # locked config is the fastest measured (paper Fig 3b: small batch ->
    # fewer chips wins over the full-node default)
    durs = {str(e.sys_config): e.duration_s for e in rec.epochs}
    assert min(durs.values()) == durs[str({**SIM_SYS_DEFAULT, **locked})]


def test_groundtruth_reused_across_trials():
    gt = GroundTruth()
    pt = _pipetune(gt)
    pt.run_trial("lenet-mnist", "t0",
                 {"batch_size": 64, "learning_rate": 0.01}, 9)
    rec2 = pt.run_trial("lenet-mnist", "t1",
                        {"batch_size": 64, "learning_rate": 0.02}, 9)
    assert rec2.gt_hit and rec2.probe_epochs == 0


def test_gt_hit_skips_probing_and_is_faster():
    gt = GroundTruth()
    pt = _pipetune(gt)
    r_cold = pt.run_trial("cnn-news20", "c0",
                          {"batch_size": 64, "learning_rate": 0.01}, 9)
    r_warm = pt.run_trial("cnn-news20", "c1",
                          {"batch_size": 64, "learning_rate": 0.01}, 9)
    assert r_warm.train_time <= r_cold.train_time


def test_pipetune_matches_v1_accuracy_with_less_time():
    job = HPTJob(workload="lenet-mnist", space=_space(), max_epochs=9, seed=0)
    v1 = TuneV1(SimBackend())
    res1 = v1.run_job(job, scheduler="random", n_trials=6)
    gt = GroundTruth()
    pt = _pipetune(gt)
    resp = pt.run_job(job, scheduler="random", n_trials=6)
    assert abs(resp.best_accuracy - res1.best_accuracy) < 0.02
    assert resp.tuning_time_s < res1.tuning_time_s


def test_tunev2_trades_accuracy():
    job = HPTJob(workload="lenet-mnist", space=_space(), max_epochs=9, seed=0)
    v1 = TuneV1(SimBackend()).run_job(job, scheduler="random", n_trials=8)
    v2 = TuneV2(SimBackend(), SimSystemSpace()).run_job(
        job, scheduler="random", n_trials=8)
    # V2 optimizes accuracy/time -> the chosen model is worse (paper §4)
    assert v2.best_accuracy <= v1.best_accuracy + 1e-6


def test_short_trials_do_not_poison_groundtruth():
    gt = GroundTruth()
    pt = _pipetune(gt)
    pt.run_trial("lenet-mnist", "s0",
                 {"batch_size": 64, "learning_rate": 0.01}, 1)
    # 1-epoch trial saw only the default config: must not be stored
    assert len(gt.entries) == 0
