"""Per-arch smoke tests (reduced configs) + decode/forward agreement."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import encdec, transformer as T

LM_ARCHS = [a for a in configs.ARCH_IDS if a != "whisper-small"]


def _batch(cfg, B=2, S=16, key=0):
    k = jax.random.PRNGKey(key)
    if getattr(cfg, "takes_embeddings", False) and cfg.family == "vlm":
        return {"embeddings": jax.random.normal(k, (B, S, cfg.d_model)),
                "labels": jax.random.randint(k, (B, S), 0, cfg.vocab)}
    toks = jax.random.randint(k, (B, S), 0, cfg.vocab)
    return {"tokens": toks, "labels": toks}


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_forward_and_loss(arch):
    cfg = configs.get_reduced(arch)
    params = T.init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    logits, aux = T.forward(params, batch, cfg)
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert jnp.isfinite(logits).all()
    loss, metrics = T.loss_fn(params, batch, cfg)
    assert jnp.isfinite(loss)
    assert 0.0 <= float(metrics["accuracy"]) <= 1.0


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_train_step_no_nans(arch):
    from repro.launch import steps
    from repro.optim import optimizers
    cfg = configs.get_reduced(arch)
    opt = optimizers.adamw(1e-3)
    sys = T.SystemConfig(microbatches=2)
    step = steps.make_train_step(cfg, sys, opt)
    state = steps.make_train_state(jax.random.PRNGKey(0), cfg, opt)
    batch = _batch(cfg, B=4)
    state2, metrics = jax.jit(step)(state, batch)
    assert jnp.isfinite(metrics["loss"])
    assert int(state2["step"]) == 1
    # params actually changed
    moved = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         state["params"], state2["params"])
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ["yi-34b", "mixtral-8x22b", "qwen3-0.6b",
                                  "recurrentgemma-9b", "xlstm-350m",
                                  "qwen2-moe-a2.7b"])
def test_decode_matches_forward(arch):
    cfg = configs.get_reduced(arch)
    if cfg.family == "moe":     # avoid capacity drops in the parallel path
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = T.init(jax.random.PRNGKey(1), cfg)
    S = 12
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, S), 0, cfg.vocab)
    logits_par, _ = T.forward(params, {"tokens": toks}, cfg)
    dtype = jnp.float32 if cfg.family == "ssm" else jnp.bfloat16
    cache = T.init_cache(cfg, 2, S, dtype=dtype)
    errs = []
    for t in range(S):
        lg, cache = T.decode_step(params, cache, toks[:, t:t + 1],
                                  jnp.int32(t), cfg)
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - logits_par[:, t]))))
    assert max(errs) < 0.15, f"decode drift {max(errs)}"


@pytest.mark.parametrize("arch", ["yi-34b", "mixtral-8x22b"])
def test_prefill_then_decode_continues(arch):
    """Prefill S tokens, then decode several more; must track the parallel
    forward (exercises the ring layout incl. SWA slot alignment)."""
    from repro.launch import steps
    cfg = configs.get_reduced(arch)
    if cfg.family == "moe":
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = T.init(jax.random.PRNGKey(1), cfg)
    S, EXTRA = 12, 4
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, S + EXTRA), 0,
                              cfg.vocab)
    sys = T.SystemConfig()
    prefill = steps.make_prefill_step(cfg, sys, max_len=S + EXTRA)
    logits, cache = prefill(params, {"tokens": toks[:, :S]})
    full, _ = T.forward(params, {"tokens": toks}, cfg)
    assert float(jnp.max(jnp.abs(logits[:, 0] - full[:, S - 1]))) < 0.15
    for t in range(S, S + EXTRA):
        lg, cache = T.decode_step(params, cache, toks[:, t:t + 1],
                                  jnp.int32(t), cfg)
        err = float(jnp.max(jnp.abs(lg[:, 0] - full[:, t])))
        assert err < 0.2, f"pos {t}: {err}"


def test_whisper_forward_decode():
    cfg = configs.get_reduced("whisper-small")
    params = encdec.init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 10
    frames = jax.random.normal(jax.random.PRNGKey(1),
                               (B, cfg.n_enc_frames, cfg.d_model))
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)
    logits, _ = encdec.forward(params, {"frames": frames, "tokens": toks}, cfg)
    assert logits.shape == (B, S, cfg.padded_vocab)
    # teacher-forced decode agreement
    enc = encdec.encode(params, frames, cfg)
    cache = encdec.init_cache(cfg, B, S, dtype=jnp.float32)
    ck, cv = encdec.build_cross_cache(params, enc, cfg, dtype=jnp.float32)
    cache["cross_k"], cache["cross_v"] = ck, cv
    errs = []
    for t in range(S):
        lg, cache = encdec.decode_step(params, cache, toks[:, t:t + 1],
                                       jnp.int32(t), cfg)
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - logits[:, t]))))
    assert max(errs) < 0.1


def test_swa_window_restricts_context():
    """With window w, token t must not see tokens <= t - w."""
    cfg = dataclasses.replace(configs.get_reduced("mixtral-8x22b"), window=4)
    params = T.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0, cfg.vocab)
    logits1, _ = T.forward(params, {"tokens": toks}, cfg)
    # perturb a token far outside every later window
    toks2 = toks.at[0, 0].set((toks[0, 0] + 1) % cfg.vocab)
    logits2, _ = T.forward(params, {"tokens": toks2}, cfg)
    # positions >= window * n_layers receptive field... single layer window=4,
    # 2 layers -> receptive field 8; position 11 must be unaffected
    diff = float(jnp.max(jnp.abs(logits1[0, 11] - logits2[0, 11])))
    assert diff < 1e-4, f"SWA leaked context: {diff}"


def test_hybrid_group_structure():
    cfg = configs.get_config("recurrentgemma-9b")
    assert cfg.hybrid_groups == 12 and cfg.hybrid_tail == 2
    assert cfg.hybrid_groups * 3 + cfg.hybrid_tail == cfg.n_layers == 38


def test_ssm_group_structure():
    cfg = configs.get_config("xlstm-350m")
    assert cfg.ssm_groups * (cfg.mlstm_per_slstm + 1) == cfg.n_layers == 24


def test_int8_kv_cache_decode_close():
    """int8 KV cache tracks the fp-cache decode (argmax-stable)."""
    cfg = configs.get_reduced("yi-34b")
    params = T.init(jax.random.PRNGKey(1), cfg)
    S = 12
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, S), 0, cfg.vocab)
    logits_par, _ = T.forward(params, {"tokens": toks}, cfg)
    cache = T.init_cache(cfg, 2, S, quant=True)
    agree, errs = 0, []
    for t in range(S):
        lg, cache = T.decode_step(params, cache, toks[:, t:t + 1],
                                  jnp.int32(t), cfg)
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - logits_par[:, t]))))
        agree += int((jnp.argmax(lg[:, 0], -1)
                      == jnp.argmax(logits_par[:, t], -1)).all())
    assert max(errs) < 0.5
    assert agree >= S - 1
