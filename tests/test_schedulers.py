"""Scheduler math: HyperBand brackets, budget, planted-optimum recovery."""
import math

import numpy as np
import pytest

from repro.core.job import Param, SearchSpace
from repro.core.schedulers import ASHA, GridSearch, HyperBand, RandomSearch


def _space():
    return SearchSpace([Param("x", "float", 0.0, 1.0)])


def _planted(x_opt=0.7):
    """score(hp, epochs) rises with epochs; best at x=x_opt."""
    calls = []

    def evaluate(tid, hp, epochs):
        calls.append((tid, epochs))
        return (1.0 - (hp["x"] - x_opt) ** 2) * (1 - math.exp(-epochs))
    return evaluate, calls


def test_hyperband_bracket_structure():
    hb = HyperBand(_space(), R=9, eta=3)
    brackets = hb.brackets()
    assert [b["s"] for b in brackets] == [2, 1, 0]
    # standard hyperband: n = ceil(B/R * eta^s / (s+1))
    assert brackets[0]["n"] == 9 and brackets[0]["r"] == 1
    assert brackets[-1]["r"] == 9


def test_hyperband_finds_planted_optimum():
    ev, calls = _planted()
    best, score = HyperBand(_space(), R=9, eta=3, seed=0).run(ev)
    assert abs(best["x"] - 0.7) < 0.25
    assert score > 0.9
    # resource accounting: trials get monotonically growing budgets per rung
    assert max(e for _, e in calls) == 9


def test_random_and_grid_and_asha():
    ev, _ = _planted()
    for sched in [RandomSearch(_space(), n_trials=20, epochs=5, seed=1),
                  GridSearch(_space(), per_dim=9, epochs=5),
                  ASHA(_space(), max_epochs=9, n_trials=20, seed=1)]:
        best, score = sched.run(ev)
        assert abs(best["x"] - 0.7) < 0.25, type(sched).__name__


def test_asha_prunes_bad_trials():
    """Bad trials must stop at low rungs (fewer total epochs than full runs)."""
    ev, calls = _planted()
    ASHA(_space(), max_epochs=9, n_trials=30, seed=0).run(ev)
    per_trial = {}
    for tid, e in calls:
        per_trial[tid] = max(per_trial.get(tid, 0), e)
    full = sum(1 for v in per_trial.values() if v >= 9)
    assert full < len(per_trial) / 2


def test_pbt_improves_over_initial_population():
    from repro.core.schedulers import PBT
    ev, _ = _planted()
    pbt = PBT(_space(), population=8, total_epochs=9, interval=3, seed=0)
    best, score = pbt.run(ev)
    assert pbt.clone_events > 0          # exploit/explore actually fired
    assert abs(best["x"] - 0.7) < 0.3
    assert score > 0.85


def test_pbt_clone_transfers_trial_state():
    from repro.cluster.sim import SimBackend
    from repro.core import TuneV1
    from repro.core.job import HPTJob, Param
    from repro.core.job import SearchSpace as SS
    job = HPTJob(workload="lenet-mnist",
                 space=SS([Param("learning_rate", "log", 0.001, 0.1)]),
                 max_epochs=6)
    r = TuneV1(SimBackend())
    res = r.run_job(job, scheduler="pbt", population=4, interval=3)
    # cloned trials carry forward epochs (no trial restarted from epoch 0
    # after an exploit)
    assert res.best_accuracy > 0.8
