"""§Roofline report: per (arch x shape x mesh) terms from the dry-run JSON.

Reads the records produced by ``python -m repro.launch.dryrun --all --out f``
and prints the roofline table: three terms, dominant bottleneck, MODEL_FLOPS
ratio, and the projected MFU. ``--pick`` lists the three hillclimb targets
(worst roofline fraction / most collective-bound / most paper-representative).
"""
from __future__ import annotations

import argparse
import json
from typing import List


def load(path):
    with open(path) as f:
        return [r for r in json.load(f) if r.get("status") == "ok"]


def table(recs: List[dict]):
    hdr = (f"{'arch':20s} {'shape':12s} {'mesh':8s} {'comp[s]':>9s} "
           f"{'mem[s]':>9s} {'mem*[s]':>9s} {'coll[s]':>9s} {'dom':>5s} "
           f"{'useful':>7s} {'MFU':>6s} {'GB/dev':>7s}")
    print(hdr)
    print("-" * len(hdr))
    for r in recs:
        t = r["roofline"]
        print(f"{r['arch']:20s} {r['shape']:12s} {r['mesh']:8s} "
              f"{t['compute_s']:9.2e} {t['memory_s']:9.2e} "
              f"{t['memory_kernelized_s']:9.2e} {t['collective_s']:9.2e} "
              f"{t['dominant'][:4]:>5s} {t['useful_flop_fraction']:7.3f} "
              f"{t['mfu']:6.3f} {r.get('per_device_gb', 0):7.2f}")


def pick_targets(recs: List[dict]):
    """The three §Perf hillclimb cells."""
    train = [r for r in recs if r["shape"] == "train_4k"]
    by_mfu = sorted(train, key=lambda r: r["roofline"]["mfu"])
    worst = by_mfu[0]
    coll = max(recs, key=lambda r: r["roofline"]["collective_s"]
               / max(r["roofline"]["step_time_s"], 1e-12))
    # most representative of the paper: the biggest train cell (system-param
    # tuning targets training jobs; mixtral train_4k is the flagship)
    rep = next((r for r in train if r["arch"] == "mixtral-8x22b"), train[-1])
    return {"worst_mfu": worst, "most_collective": coll,
            "representative": rep}


def run(records=None):
    """Roofline summary over in-process records (the smoke path
    ``benchmarks/run.py`` drives): dry-runs the quick hillclimb variants
    when none are given, prints the table, and returns the headline terms."""
    if records is None:
        from benchmarks import hillclimb
        records = hillclimb.run(quick=True)
    recs = [r for r in records if r.get("status") == "ok"]
    if not recs:
        raise RuntimeError("no ok dry-run records to summarize")
    table(recs)
    by_mfu = max(recs, key=lambda r: r["roofline"]["mfu"])
    doms = sorted({r["roofline"]["dominant"] for r in recs})
    return {"n": len(recs), "dominant": "/".join(doms),
            "mfu_max": by_mfu["roofline"]["mfu"]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("path", nargs="?", default="dryrun_single.json")
    ap.add_argument("--pick", action="store_true")
    a = ap.parse_args()
    recs = load(a.path)
    table(recs)
    if a.pick:
        t = pick_targets(recs)
        print("\nhillclimb targets:")
        for k, r in t.items():
            print(f"  {k}: {r['arch']} x {r['shape']} "
                  f"(dom={r['roofline']['dominant']}, "
                  f"mfu={r['roofline']['mfu']:.3f})")


if __name__ == "__main__":
    main()
