"""Paper Fig 1: tuning cost grows exponentially with the number of tuned
hyperparameters (grid search, 3 values each), priced on small/medium/large
cloud instances."""
from __future__ import annotations

import argparse
import json

from repro.api import Experiment
from repro.core.job import HPTJob, Param, SearchSpace

INSTANCE_USD_PER_H = {"small": 0.8, "medium": 1.9, "large": 4.1}
INSTANCE_SPEEDUP = {"small": 1.0, "medium": 1.8, "large": 3.1}

ALL_PARAMS = [
    Param("batch_size", "choice", choices=(32, 128, 1024)),
    Param("learning_rate", "choice", choices=(0.001, 0.01, 0.1)),
    Param("dropout", "choice", choices=(0.0, 0.25, 0.5)),
    Param("embed_dim", "choice", choices=(50, 100, 300)),
    Param("momentum", "choice", choices=(0.0, 0.9, 0.99)),
    Param("weight_decay", "choice", choices=(0.0, 0.01, 0.1)),
]


def run(max_params=6, epochs=5):
    rows = []
    for n in range(1, max_params + 1):
        job = HPTJob(workload="lenet-mnist", space=SearchSpace(ALL_PARAMS[:n]),
                     max_epochs=epochs)
        res = (Experiment(job).with_tuner("v1").with_backend("sim")
               .with_scheduler("grid", per_dim=3).run())
        t = res.tuning_time_s
        row = {"n_params": n, "n_trials": len(res.records),
               "tuning_time_s": t}
        for inst, usd in INSTANCE_USD_PER_H.items():
            row[f"cost_{inst}_usd"] = usd * (t / INSTANCE_SPEEDUP[inst]) / 3600
        rows.append(row)
    return rows


def main(max_params=4):
    rows = run(max_params)
    print(f"{'#params':>7s} {'trials':>7s} {'time[s]':>10s} "
          f"{'$small':>8s} {'$large':>8s}")
    for r in rows:
        print(f"{r['n_params']:7d} {r['n_trials']:7d} "
              f"{r['tuning_time_s']:10.1f} {r['cost_small_usd']:8.2f} "
              f"{r['cost_large_usd']:8.2f}")
    growth = rows[-1]["tuning_time_s"] / rows[0]["tuning_time_s"]
    print(f"growth {rows[0]['n_params']}->{rows[-1]['n_params']} params: "
          f"{growth:.0f}x (exponential in #params)")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--max-params", type=int, default=4)
    ap.add_argument("--out", default=None)
    a = ap.parse_args()
    rows = main(a.max_params)
    if a.out:
        json.dump(rows, open(a.out, "w"), indent=1)
