"""Shared benchmark scaffolding (all construction goes through repro.api)."""
from __future__ import annotations

import time

import numpy as np

from repro.api import Experiment
from repro.core import GroundTruth, SearchSpace, SystemSpace
from repro.core.backends import RealBackend
from repro.core.job import HPTJob, Param

# benchmark label -> registry tuner name
TUNERS = {"TuneV1": "v1", "TuneV2": "v2", "PipeTune": "pipetune"}


def paper_space(small=True) -> SearchSpace:
    """Paper §7.1.3 hyperparameters (epochs handled by the scheduler)."""
    bs = (32, 64) if small else (32, 64, 128, 256, 512, 1024)
    return SearchSpace([
        Param("batch_size", "choice", choices=bs),
        Param("dropout", "float", 0.0, 0.5),
        Param("learning_rate", "log", 0.001, 0.1),
    ])


def real_backend(quick=True) -> RealBackend:
    if quick:
        return RealBackend(n_train=768, n_eval=192, steps_per_epoch=6)
    return RealBackend(n_train=4096, n_eval=1024, steps_per_epoch=24)


def real_sys_space() -> SystemSpace:
    # precision stays fp32 on the CPU backend: bf16 here is software-emulated
    # (5-20x slower), which is a host artifact, not a property of the TPU
    # deployment target the tuner is meant to learn about.
    return SystemSpace(remat=("none", "block"), microbatches=(1, 2, 4),
                       precision=("fp32",))


def experiment(job: HPTJob, tuner: str, backend="sim", gt=None, seed=0,
               max_probes=6, **backend_kw) -> Experiment:
    """An Experiment pre-wired the way the benchmarks compare approaches:
    `tuner` is a benchmark label ("PipeTune") or registry name ("pipetune");
    PipeTune shares `gt` across jobs (its cross-job learning)."""
    name = TUNERS.get(tuner, tuner)
    kw = {"max_probes": max_probes} if name == "pipetune" else {}
    if backend == "sim":
        backend_kw.setdefault("seed", seed)
    exp = (Experiment(job)
           .with_tuner(name, **kw)
           .with_backend(backend, **backend_kw))
    if name == "pipetune":
        exp.with_groundtruth(gt or GroundTruth())
    return exp


def sim_runners(gt=None, seed=0, max_probes=6):
    """TrialRunner factories over SimBackend, keyed by benchmark label
    (``ClusterSim`` takes one factory per job)."""
    gt = gt or GroundTruth()
    dummy = HPTJob(workload="lenet-mnist", space=paper_space())
    return {label: experiment(dummy, label, gt=gt, seed=seed,
                              max_probes=max_probes).build_runner
            for label in TUNERS}


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.s = time.time() - self.t0


def csv_row(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
