"""Shared benchmark scaffolding."""
from __future__ import annotations

import time

import numpy as np

from repro.cluster.sim import SimBackend, SimSystemSpace
from repro.core import (GroundTruth, PipeTune, TuneV1, TuneV2, SearchSpace,
                        SystemSpace)
from repro.core.backends import RealBackend
from repro.core.job import HPTJob, Param


def paper_space(small=True) -> SearchSpace:
    """Paper §7.1.3 hyperparameters (epochs handled by the scheduler)."""
    bs = (32, 64) if small else (32, 64, 128, 256, 512, 1024)
    return SearchSpace([
        Param("batch_size", "choice", choices=bs),
        Param("dropout", "float", 0.0, 0.5),
        Param("learning_rate", "log", 0.001, 0.1),
    ])


def real_backend(quick=True) -> RealBackend:
    if quick:
        return RealBackend(n_train=768, n_eval=192, steps_per_epoch=6)
    return RealBackend(n_train=4096, n_eval=1024, steps_per_epoch=24)


def real_sys_space() -> SystemSpace:
    # precision stays fp32 on the CPU backend: bf16 here is software-emulated
    # (5-20x slower), which is a host artifact, not a property of the TPU
    # deployment target the tuner is meant to learn about.
    return SystemSpace(remat=("none", "block"), microbatches=(1, 2, 4),
                       precision=("fp32",))


def sim_runners(gt=None):
    gt = gt or GroundTruth()
    return {
        "TuneV1": lambda: TuneV1(SimBackend()),
        "TuneV2": lambda: TuneV2(SimBackend(), SimSystemSpace()),
        "PipeTune": lambda: PipeTune(SimBackend(), SimSystemSpace(),
                                     groundtruth=gt, max_probes=6),
    }


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.s = time.time() - self.t0


def csv_row(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
