"""Chaos recovery benchmark: SIGKILL a live worker mid-run and measure
how fast the elastic path heals.

Runs the ``sigkill_worker`` scenario from the reusable pack (two real
``python -m repro.worker`` subprocesses behind a coordinator, one killed
mid-wave) and reports the recovery-time headline: seconds from the kill
to the victim's retirement plus how many orphaned trials re-placed —
with the scenario's own SLO verdicts (no lost/repeated epochs,
bit-identical results vs the no-fault serial run) required to hold.

Also times the no-fault observation overhead: the same in-process tuning
run with the event bus dark vs. fully instrumented (memory sink + JSONL
trace), as a sanity bound on what emission costs the hot path.

Run directly for the full version (every scenario in the pack):
    PYTHONPATH=src python -m benchmarks.chaos --full
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time


def _overhead(repeats: int = 3) -> dict:
    """Same cluster-executor tuning run, bus dark vs. instrumented."""
    from repro.api import Experiment, registry
    from repro.core.job import HPTJob, Param, SearchSpace
    from repro.obs.events import EventBus
    from repro.obs.sinks import MemorySink, attach_trace

    space = SearchSpace([
        Param("batch_size", "choice", choices=(32, 64)),
        Param("learning_rate", "log", 0.001, 0.1),
    ])
    job = HPTJob(workload="lenet-mnist", space=space, max_epochs=6, seed=0)

    def one_run(bus=None):
        ex = registry.make_executor("cluster", n_nodes=4)
        if bus is not None:
            ex.attach_bus(bus)
        t0 = time.perf_counter()
        res = (Experiment(job).with_tuner("v1").with_backend("sim")
               .with_scheduler("hyperband").run(executor=ex))
        dt = time.perf_counter() - t0
        ex.close()
        return dt, res.best_score

    dark, lit, events = [], [], 0
    with tempfile.TemporaryDirectory() as td:
        for i in range(repeats):
            dt, score_dark = one_run()
            dark.append(dt)
            bus = EventBus()
            mem = MemorySink()
            bus.add_sink(mem)
            sink = attach_trace(bus, os.path.join(td, f"t{i}.jsonl"))
            dt, score_lit = one_run(bus)
            sink.close()
            lit.append(dt)
            events = len(mem.records)
            assert score_lit == score_dark          # observation is passive
    base, instrumented = min(dark), min(lit)
    return {"base_s": base, "instrumented_s": instrumented,
            "overhead_pct": 100.0 * (instrumented / base - 1.0),
            "events_per_run": events}


def run(full: bool = False) -> dict:
    from repro.obs.chaos import run_scenario
    from repro.obs.scenarios import SCENARIOS

    names = list(SCENARIOS) if full else ["sigkill_worker"]
    reports = {}
    for name in names:
        report = run_scenario(SCENARIOS[name])
        if not report.passed:
            raise RuntimeError(f"chaos scenario {name} violated its SLOs:\n"
                               + report.summary())
        reports[name] = report
    head = reports["sigkill_worker"]
    out = {
        "recovery_s": head.recovery_s,
        "replaced": head.replaced,
        "n_events": head.n_events,
        "wall_s": head.wall_s,
        "scenarios_passed": len(reports),
        "overhead": _overhead(),
        "reports": {n: {"passed": r.passed, "recovery_s": r.recovery_s,
                        "replaced": r.replaced, "wall_s": r.wall_s}
                    for n, r in reports.items()},
    }
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--full", action="store_true",
                    help="run every scenario in the pack, not just the "
                         "sigkill_worker headline")
    args = ap.parse_args()
    out = run(full=args.full)
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
