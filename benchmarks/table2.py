"""Paper Table 2: accuracy / training time / tuning time per approach,
LeNet on MNIST(-like). Real training on CPU (RealBackend).

Approaches: Arbitrary (fixed mediocre hparams, no tuning), Tune V1, Tune V2,
PipeTune. The paper's numbers: PipeTune matches V1 accuracy, matches V2
training time, and has the lowest tuning time.
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from benchmarks import common
from repro.api import Experiment
from repro.core.job import HPTJob


def run(quick=True, workload="lenet-mnist", seed=0):
    space = common.paper_space(small=quick)
    n_trials = 6 if quick else 12
    epochs = 6 if quick else 9
    job = HPTJob(workload=workload, space=space, max_epochs=epochs, seed=seed)
    sys_space = common.real_sys_space()
    rows = {}

    # Arbitrary: fixed so-so hyperparameters, single training run
    arb = (Experiment(job).with_tuner("v1")
           .with_backend(common.real_backend(quick)).build_runner())
    rec = arb.run_trial(workload, "arbitrary",
                        {"batch_size": 1024 if not quick else 64,
                         "learning_rate": 0.08, "dropout": 0.45}, epochs)
    rows["Arbitrary"] = dict(accuracy=rec.accuracy,
                             training_time_s=rec.train_time,
                             tuning_time_s=0.0, energy_j=rec.energy)

    def best_train_time(res):
        br = res.best_record
        return br.train_time if br else 0.0

    for name in ("TuneV1", "TuneV2", "PipeTune"):
        res = (common.experiment(job, name,
                                 backend=common.real_backend(quick),
                                 max_probes=4)
               .with_sys_space(sys_space)
               .with_scheduler("random", n_trials=n_trials)
               .run())
        rows[name] = dict(accuracy=res.best_accuracy,
                          training_time_s=best_train_time(res),
                          tuning_time_s=res.tuning_time_s,
                          energy_j=res.energy_j)
    return rows


def main(quick=True):
    rows = run(quick=quick)
    print(f"{'Approach':10s} {'Acc[%]':>8s} {'Train[s]':>9s} {'Tune[s]':>9s}")
    for name, r in rows.items():
        print(f"{name:10s} {100*r['accuracy']:8.2f} "
              f"{r['training_time_s']:9.2f} {r['tuning_time_s']:9.2f}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default=None)
    a = ap.parse_args()
    rows = main(quick=not a.full)
    if a.out:
        json.dump(rows, open(a.out, "w"), indent=1)
