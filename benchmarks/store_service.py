"""Shared ground-truth service benchmark: what the client-side centroid
cache buys on the hot lookup path, and that socket and in-proc clients
agree bit-for-bit on a warm store.

Three lookup paths, slowest to fastest:

    naive    ship every profile to the server, evaluate there (1 RPC each)
    scalar   ``StoreClient.lookup`` — local centroid model, freshness via
             the version piggybacked on earlier responses (0 RPC when warm)
    batched  ``StoreClient.lookup_many`` — one freshness check + one
             vectorized ``evaluate_many`` per wave (the dispatch hot path:
             ``run_wave`` resolves a whole wave of probes at once)

``cached_lookups_per_s`` — the headline CI tracks — measures the batched
wave path; ``scalar_lookups_per_s`` is reported alongside so the
one-at-a-time win (no per-lookup version ping) stays visible.

Run directly for the full version:  PYTHONPATH=src python -m benchmarks.store_service
"""
from __future__ import annotations

import time

import numpy as np

from repro.service import (GroundTruthService, GroundTruthTCPServer,
                           InprocTransport, SocketTransport, StoreClient)


def _warm_service(path=None, n_workloads=4, per_workload=4):
    svc = GroundTruthService(path=path)
    rng = np.random.RandomState(0)
    for w in range(n_workloads):
        base = np.zeros(58)
        base[w * 5:(w + 1) * 5] = 10.0 + 5.0 * w
        for i in range(per_workload):
            svc.handle({"op": "add", "profile":
                        (base + rng.randn(58) * 0.05).tolist(),
                        "workload": f"wl-{w}", "sys_config": {"chips": 4 + w},
                        "objective": 0.9})
    return svc


def _probe_set(n, seed=7):
    rng = np.random.RandomState(seed)
    out = []
    for i in range(n):
        base = np.zeros(58)
        w = i % 4
        base[w * 5:(w + 1) * 5] = 10.0 + 5.0 * w
        out.append(base + rng.randn(58) * 0.05)
    return out


def run(n_lookups: int = 200, quick: bool = True) -> dict:
    import threading

    svc = _warm_service()
    probes = _probe_set(n_lookups)
    server = GroundTruthTCPServer(("127.0.0.1", 0), svc)
    threading.Thread(target=server.serve_forever, daemon=True).start()
    addr = ("127.0.0.1", server.server_address[1])

    # naive remote lookups: ship the profile, run the model server-side
    transport = SocketTransport(*addr)
    t0 = time.perf_counter()
    naive = [transport.request({"op": "lookup", "profile": p.tolist()})
             for p in probes]
    t_naive = time.perf_counter() - t0
    transport.close()

    # scalar cached client: local centroid evaluation, freshness from the
    # version piggybacked on the warm-up responses (zero RPC per lookup)
    sock_client = StoreClient(SocketTransport(*addr))
    sock_client.lookup(probes[0])                       # model warm-up
    t0 = time.perf_counter()
    cached = [sock_client.lookup(p) for p in probes]
    t_scalar = time.perf_counter() - t0

    # batched wave path: one freshness check + one vectorized evaluate
    t0 = time.perf_counter()
    batched = sock_client.lookup_many(probes)
    t_batched = time.perf_counter() - t0
    sock_client.close()
    server.shutdown()

    # every path must agree with the in-proc client bit for bit
    inproc = StoreClient(InprocTransport(svc))
    local = [inproc.lookup(p) for p in probes]
    agree = all(s == l for s, l in zip(cached, local)) and \
        all(b == l for b, l in zip(batched, local))
    hit_rate = sock_client.hits / max(1, sock_client.hits + sock_client.misses)
    return {"n_lookups": n_lookups,
            "cached_lookups_per_s": n_lookups / max(t_batched, 1e-9),
            "scalar_lookups_per_s": n_lookups / max(t_scalar, 1e-9),
            "naive_lookups_per_s": n_lookups / max(t_naive, 1e-9),
            "cache_speedup": t_naive / max(t_batched, 1e-9),
            "hit_rate": hit_rate, "socket_agrees": agree}


if __name__ == "__main__":
    out = run(n_lookups=2000, quick=False)
    for k, v in out.items():
        print(f"{k}: {v}")
