"""Shared ground-truth service benchmark: what the client-side centroid
cache buys on the hot lookup path, and that socket and in-proc clients
agree bit-for-bit on a warm store.

Run directly for the full version:  PYTHONPATH=src python -m benchmarks.store_service
"""
from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from repro.service import (GroundTruthService, GroundTruthTCPServer,
                           InprocTransport, SocketTransport, StoreClient)


def _warm_service(path=None, n_workloads=4, per_workload=4):
    svc = GroundTruthService(path=path)
    rng = np.random.RandomState(0)
    for w in range(n_workloads):
        base = np.zeros(58)
        base[w * 5:(w + 1) * 5] = 10.0 + 5.0 * w
        for i in range(per_workload):
            svc.handle({"op": "add", "profile":
                        (base + rng.randn(58) * 0.05).tolist(),
                        "workload": f"wl-{w}", "sys_config": {"chips": 4 + w},
                        "objective": 0.9})
    return svc


def _probe_set(n, seed=7):
    rng = np.random.RandomState(seed)
    out = []
    for i in range(n):
        base = np.zeros(58)
        w = i % 4
        base[w * 5:(w + 1) * 5] = 10.0 + 5.0 * w
        out.append(base + rng.randn(58) * 0.05)
    return out


def run(n_lookups: int = 200, quick: bool = True) -> dict:
    import threading

    svc = _warm_service()
    probes = _probe_set(n_lookups)
    server = GroundTruthTCPServer(("127.0.0.1", 0), svc)
    threading.Thread(target=server.serve_forever, daemon=True).start()
    addr = ("127.0.0.1", server.server_address[1])

    # naive remote lookups: ship the profile, run the model server-side
    transport = SocketTransport(*addr)
    t0 = time.perf_counter()
    naive = [transport.request({"op": "lookup", "profile": p.tolist()})
             for p in probes]
    t_naive = time.perf_counter() - t0
    transport.close()

    # cached client: tiny version ping + local centroid evaluation
    sock_client = StoreClient(SocketTransport(*addr))
    t0 = time.perf_counter()
    cached = [sock_client.lookup(p) for p in probes]
    t_cached = time.perf_counter() - t0
    sock_client.close()
    server.shutdown()

    # the in-proc client must agree with the socket client bit for bit
    inproc = StoreClient(InprocTransport(svc))
    local = [inproc.lookup(p) for p in probes]
    agree = all(s0 == s1 and c0 == c1 for (s0, c0), (s1, c1)
                in zip(cached, local))
    hit_rate = sock_client.hits / max(1, sock_client.hits + sock_client.misses)
    return {"n_lookups": n_lookups,
            "cached_lookups_per_s": n_lookups / max(t_cached, 1e-9),
            "naive_lookups_per_s": n_lookups / max(t_naive, 1e-9),
            "cache_speedup": t_naive / max(t_cached, 1e-9),
            "hit_rate": hit_rate, "socket_agrees": agree}


if __name__ == "__main__":
    out = run(n_lookups=2000, quick=False)
    for k, v in out.items():
        print(f"{k}: {v}")
