"""Kernel autotuning headline: tuned-vs-default wall time via the find-db.

``run(quick=True)`` tunes the smoke workloads into an *isolated*
``KernelConfigDB`` (never the process-wide one — the bench must measure the
tuner, not inherit someone else's warm entries), asserts the acceptance
bar — best tuned config >= 1.2x faster than the hand-picked default on at
least one (kernel, shape) — and that a warm re-tune resolves every workload
from the find-db with **zero** tuning trials and the identical config.

Full mode (run this module directly) sweeps every preset workload and
prints the tuned-vs-default table.
"""
import argparse
import json

# the two smoke shapes where block choice is measurable in seconds, not
# minutes; train-smoke is excluded here (it's the hillclimb bench's job)
QUICK_WORKLOADS = ("flash-fwd-smoke", "mlstm-smoke")


def run(quick=True, workloads=None, reps=5, warmup=2, seed=0):
    """Tune ``workloads`` cold, then re-resolve warm. Returns
    ``{results, warm, best, warm_trials}``; raises RuntimeError when the
    speedup bar or the zero-trial warm path fails (bench_elastic idiom —
    an assert here is a broken subsystem, not a slow one)."""
    from repro.core.groundtruth import KernelConfigDB
    from repro.kernels import tune

    if workloads is None:
        workloads = (QUICK_WORKLOADS if quick
                     else tuple(sorted(tune.PRESETS)))
    db = KernelConfigDB()
    results = [tune.tune_kernel(wl, db=db, reps=reps, warmup=warmup,
                                seed=seed) for wl in workloads]
    for r in results:
        if r["source"] != "tuned":
            raise RuntimeError(
                f"cold tune of {r['workload']!r} resolved from "
                f"{r['source']} — isolated db was not empty?")

    # warm path: every workload must come back from the find-db, zero
    # trials, config bit-identical to what the cold run persisted
    warm = [tune.tune_kernel(wl, db=db) for wl in workloads]
    warm_trials = sum(w["trials"] for w in warm)
    if warm_trials != 0:
        raise RuntimeError(f"warm re-tune ran {warm_trials} trials "
                           f"(want 0: the find-db fast path is broken)")
    for cold, hot in zip(results, warm):
        if hot["source"] != "find-db" or hot["config"] != cold["config"]:
            raise RuntimeError(
                f"warm lookup for {cold['workload']!r} returned "
                f"{hot['config']} from {hot['source']} "
                f"(tuned {cold['config']})")

    best = max(results, key=lambda r: r["speedup"] or 0.0)
    if quick and best["speedup"] < 1.2:
        raise RuntimeError(
            "kernel tuning found no config >=1.2x over defaults "
            + "; ".join(f"{r['workload']}={r['speedup']:.3f}x"
                        for r in results))
    return {"results": results, "warm": warm, "best": best,
            "warm_trials": warm_trials}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", action="append", default=None,
                    help="preset or kernel@k=v spec (repeatable; "
                    "default: all presets)")
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--warmup", type=int, default=2)
    ap.add_argument("--out", default="kernel_tune.json")
    a = ap.parse_args()
    out = run(quick=False, workloads=a.workload, reps=a.reps,
              warmup=a.warmup)
    for r in out["results"]:
        print(f"{r['workload']:20s} {json.dumps(r['config']):40s} "
              f"default={r['default_s'] * 1e3:7.2f}ms "
              f"tuned={r['tuned_s'] * 1e3:7.2f}ms "
              f"speedup={r['speedup']:.3f}x trials={r['trials']}")
    print(f"warm re-resolve: {out['warm_trials']} trials "
          f"(best {out['best']['workload']} "
          f"{out['best']['speedup']:.3f}x)")
    with open(a.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {a.out}")


if __name__ == "__main__":
    main()
