"""§Perf hillclimb driver: run system-config variants of the three target
cells and print before/after roofline terms.

Targets (picked per the methodology from the baseline table):
  * mixtral-8x22b x train_4k   — most representative (flagship training job)
  * whisper-small x train_4k   — was most collective-bound
  * xlstm-350m x train_4k      — worst roofline fraction

Each variant encodes a hypothesis; see EXPERIMENTS.md §Perf for the napkin
math and verdicts. ``run(quick=True)`` compiles reduced-config variants of
one target on a 1x1 mesh — the smoke path ``benchmarks/run.py`` drives.
(The 512-device XLA flag the production path needs is set when
``repro.launch.dryrun`` is imported.)
"""
import argparse
import copy
import json

from repro.launch import dryrun, mesh as mesh_lib

VARIANTS = {
    "mixtral-8x22b/train_4k": [
        ("baseline", {}),
        # H-A1: dots-policy remat skips the fwd recompute -> fewer weight
        # re-gathers and fewer recompute flops (predict: compute -25%,
        # memory -15%, HBM footprint up)
        ("remat=dots", {"remat": "dots"}),
        # H-A2: bigger attention chunks -> KV re-read drops with nq (S/qc)
        ("qchunk=2048", {"q_chunk": 2048, "kv_chunk": 2048}),
        # H-A3: fewer microbatches -> weights amortized over 2x tokens per
        # gather (predict: memory term down, footprint up 2x)
        ("micro=8", {"microbatches": 8}),
    ],
    "whisper-small/train_4k": [
        ("baseline", {}),
        # H-B1: tiny model over-sharded; single macro-batch amortizes weight
        # reads 16x (predict: memory term down, collective count down)
        ("micro=1", {"microbatches": 1}),
        ("micro=4", {"microbatches": 4}),
        # H-B2: no remat (activations are small) -> no recompute traffic
        ("remat=none", {"remat": "none"}),
    ],
    "xlstm-350m/train_4k": [
        ("baseline", {}),
        # H-C1: the sLSTM per-timestep matmul re-reads w_rec every step;
        # fewer microbatches amortize it over more rows (predict: memory
        # term down ~linearly in per-device microbatch size)
        ("micro=4", {"microbatches": 4}),
        ("micro=1", {"microbatches": 1}),
        # H-C2: no remat: scan-of-scan recompute doubles the sequential
        # traffic; activations are small enough to save
        ("micro=1+remat=none", {"microbatches": 1, "remat": "none"}),
    ],
}


QUICK_VARIANTS = [
    ("baseline", {}),
    ("micro=1", {"microbatches": 1}),
    ("remat=none", {"remat": "none"}),
]


def run(quick=True, arch="xlstm-350m", cache=None):
    """Smoke-scale hillclimb: reduced config, tiny train shape, 1x1 mesh.
    Returns the dry-run records (one per variant) with ``variant`` set.

    ``cache`` (a ``KernelConfigDB``) routes variants through the kernel
    config cache: a hit replays the stored record without recompiling
    (``cached=True`` on the record), a miss compiles and stores. Records
    are deep-copied across the cache boundary so callers mutating one run's
    records can't corrupt the next.
    """
    from repro import configs
    from repro.kernels import findb
    mesh = mesh_lib.make_mesh(1, 1)
    shape = configs.ShapeSpec("train_smoke", "train", 128, 8)
    hw = findb.hardware_key()
    records = []
    for name, overrides in QUICK_VARIANTS:
        key = findb.shape_key(arch=arch, cell="train_smoke", mesh="1x1",
                              variant=name)
        hit = cache.get("hillclimb", key, hw) if cache is not None else None
        if hit is not None:
            r = copy.deepcopy(hit["record"])
            r["cached"] = True
            records.append(r)
            continue
        r = dryrun.run_cell(arch, "train_smoke", mesh=mesh, reduced=True,
                            shape=shape, sys_overrides=overrides,
                            verbose=False)
        r["variant"] = name
        r["cached"] = False
        if cache is not None and r["status"] == "ok":
            cache.put("hillclimb", key, {"record": copy.deepcopy(r)},
                      hardware=hw)
        records.append(r)
    return records


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--target", default=None,
                    help="arch/shape (default: all three)")
    ap.add_argument("--out", default="hillclimb.json")
    a = ap.parse_args()
    mesh = mesh_lib.make_production_mesh()
    results = []
    targets = ([a.target] if a.target else list(VARIANTS))
    for tgt in targets:
        arch, shape = tgt.split("/")
        print(f"\n=== {tgt} ===")
        for name, overrides in VARIANTS[tgt]:
            r = dryrun.run_cell(arch, shape, mesh=mesh,
                                sys_overrides=overrides, verbose=False)
            r["variant"] = name
            results.append(r)
            if r["status"] != "ok":
                print(f"{name:22s} FAILED: {r.get('error', '?')[:120]}")
                continue
            t = r["roofline"]
            print(f"{name:22s} c/m/m*/n = {t['compute_s']:8.2e} "
                  f"{t['memory_s']:8.2e} {t['memory_kernelized_s']:8.2e} "
                  f"{t['collective_s']:8.2e}  dom={t['dominant']:10s} "
                  f"mfu={t['mfu']:.4f} gb={r['per_device_gb']:6.1f}")
    with open(a.out, "w") as f:
        json.dump(results, f, indent=1)
    print(f"\nwrote {a.out}")


if __name__ == "__main__":
    main()
