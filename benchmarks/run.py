"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per the scaffold contract, where
us_per_call is the wall time of the benchmark and derived carries its
headline result. Full (slow) versions: run each module directly with --full.

A machine-readable summary (per-benchmark wall time + headline metric)
lands in ``BENCH_results.json`` (override with ``$BENCH_OUT``) so CI can
archive the perf trajectory run over run. ``--baseline PATH`` compares this
run's per-bench wall times against a previous summary (e.g. the committed
``BENCH_results.json``) and prints a delta table, flagging anything slower
than ``--regress-threshold`` (default 1.5x); add ``--fail-on-regress`` to
turn flags into a nonzero exit (off by default — CI wall clocks are noisy,
the table in the job log is the signal).
"""
from __future__ import annotations

import argparse
import json
import os
import time

RESULTS = []                    # [{name, us_per_call, derived}] in run order


def _timed(name, fn):
    t0 = time.time()
    derived = fn()
    us = (time.time() - t0) * 1e6
    print(f"{name},{us:.0f},{derived}")
    RESULTS.append({"name": name, "us_per_call": round(us),
                    "derived": str(derived)})


def write_summary(path=None):
    path = path or os.environ.get("BENCH_OUT", "BENCH_results.json")
    payload = {"generated_at": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
               "total_wall_s": round(sum(r["us_per_call"]
                                         for r in RESULTS) / 1e6, 3),
               "benchmarks": RESULTS}
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"# wrote {path} ({len(RESULTS)} benchmarks)")


def bench_table2():
    from benchmarks import table2
    rows = table2.run(quick=True)
    pt, v1 = rows["PipeTune"], rows["TuneV1"]
    return (f"acc_pt={pt['accuracy']:.3f};acc_v1={v1['accuracy']:.3f};"
            f"tune_ratio={pt['tuning_time_s']/max(v1['tuning_time_s'],1e-9):.2f}")


def bench_fig9_10_convergence():
    from benchmarks import convergence
    out = convergence.run(quick=True)
    return (f"speedup_v1={out['TuneV1']['tuning_time']/out['PipeTune']['tuning_time']:.2f}x;"
            f"speedup_v2={out['TuneV2']['tuning_time']/out['PipeTune']['tuning_time']:.2f}x")


def bench_fig11_single_tenancy():
    from benchmarks import single_tenancy
    import numpy as np
    out = single_tenancy.run(single_tenancy.TYPE_I_II)
    red = [1 - r["PipeTune"]["tuning_time_s"] / r["TuneV1"]["tuning_time_s"]
           for r in out.values()]
    ene = [1 - r["PipeTune"]["energy_j"] / r["TuneV1"]["energy_j"]
           for r in out.values()]
    return (f"tuning_reduction_max={100*max(red):.1f}%;"
            f"energy_reduction_max={100*max(ene):.1f}%")


def bench_fig12_typeIII():
    from benchmarks import single_tenancy
    out = single_tenancy.run(single_tenancy.TYPE_III)
    red = [1 - r["PipeTune"]["tuning_time_s"] / r["TuneV1"]["tuning_time_s"]
           for r in out.values()]
    return f"tuning_reduction_max={100*max(red):.1f}%"


def bench_fig12_real_typeIII():
    """Real (non-simulated) Type-III short-epoch jobs on NumericBackend."""
    from repro.api import Experiment
    from repro.core import GroundTruth
    from repro.core.job import HPTJob, Param, SearchSpace
    space = SearchSpace([Param("block", "choice", choices=(1, 2))])
    gt = GroundTruth()
    ratios = []
    for wl in ("jacobi-rodinia", "spkmeans-rodinia", "bfs-rodinia"):
        job = HPTJob(workload=wl, space=space, max_epochs=6)
        r1 = (Experiment(job).with_tuner("v1").with_backend("numeric")
              .with_scheduler("random", n_trials=3).run())
        rp = (Experiment(job).with_tuner("pipetune", max_probes=2)
              .with_backend("numeric").with_groundtruth(gt)
              .with_scheduler("random", n_trials=3).run())
        ratios.append(rp.tuning_time_s / max(r1.tuning_time_s, 1e-9))
    import numpy as np
    return f"tune_ratio_mean={np.mean(ratios):.2f}"


def bench_fig13_14_multi_tenancy():
    from benchmarks import multi_tenancy
    out = multi_tenancy.scenario(
        ["lenet-mnist", "cnn-news20", "lenet-fashion", "lstm-news20"],
        n_jobs=8, n_nodes=4)
    v1 = out["TuneV1"]["mean_response_s"]
    pt = out["PipeTune"]["mean_response_s"]
    return f"response_reduction_vs_v1={100*(1-pt/v1):.1f}%"


def bench_async_vs_barrier():
    """AsyncASHA vs HyperBand on the event-driven cluster executor: simulated
    time to the first final-rung completion under 30% stragglers."""
    from benchmarks import multi_tenancy
    out = multi_tenancy.async_vs_barrier()
    a = out["asha-async"]["final_rung_s"]
    h = out["hyperband"]["final_rung_s"]
    ma = out["asha-async"]["makespan_s"]
    mh = out["hyperband"]["makespan_s"]
    return (f"final_rung_speedup={h/a:.2f}x;"
            f"makespan_speedup={mh/ma:.2f}x")


def bench_elastic():
    """Elastic vs static node allocation under bursty arrivals: mean job
    response time, with determinism + score-parity asserted inside."""
    from benchmarks import elastic
    out = elastic.run()
    if out["response_reduction"] <= 0:
        raise RuntimeError(
            f"elastic allocation lost to the static cluster "
            f"({out['elastic']['mean_response_s']:.0f}s vs "
            f"{out['static']['mean_response_s']:.0f}s mean response)")
    return (f"response_reduction={100 * out['response_reduction']:.1f}%;"
            f"splits={out['splits']};"
            f"reshards={out['elastic']['reshards']}")


def bench_store_service():
    """Shared-store client cache: hot lookups stay local, socket agrees.
    The headline is the wave-batched ``lookup_many`` path; the scalar
    (one-at-a-time, ping-free) rate rides along."""
    from benchmarks import store_service
    out = store_service.run(n_lookups=500, quick=True)
    if not out["socket_agrees"]:
        raise RuntimeError("socket client diverged from in-proc client")
    return (f"cache_speedup={out['cache_speedup']:.1f}x;"
            f"hit_rate={out['hit_rate']:.2f};"
            f"cached_klookups_per_s={out['cached_lookups_per_s']/1e3:.1f};"
            f"scalar_klookups_per_s={out['scalar_lookups_per_s']/1e3:.1f}")


def bench_dispatch():
    """Dispatch overhead: µs per trial action over the worker wire (real
    framing + selector server, canned trial service), JSON vs binary,
    single vs batched run_many."""
    from benchmarks import dispatch
    out = dispatch.run(n_actions=2000, batch=32, quick=True)
    return (f"us_json_single={out['us_json_single']:.1f};"
            f"us_binary_single={out['us_binary_single']:.1f};"
            f"us_json_batched={out['us_json_batched']:.1f};"
            f"us_binary_batched={out['us_binary_batched']:.1f};"
            f"batch_speedup={out['batch_speedup']:.1f}x;"
            f"codec={out['binary_codec']}")


def bench_dispatch_traced():
    """Tracing overhead on the dispatch bench: trace metadata on every
    request, RPC receipts onto an enabled bus, forwarding to a live
    collector — vs. tracing off. The acceptance bar (<5%) is asserted on
    the batched ``run_many`` path, production dispatch since the batched
    protocol landed (one receipt per wave); the legacy per-request single
    path rides along informationally."""
    from benchmarks import dispatch
    out = dispatch.run_traced(n_actions=2000, batch=32)
    if out["overhead_batched_pct"] >= 5.0:
        raise RuntimeError(
            f"tracing overhead {out['overhead_batched_pct']:.1f}% on the "
            f"batched dispatch path breaches the 5% acceptance bar "
            f"(traced {out['us_traced_batched']:.1f}us vs plain "
            f"{out['us_plain_batched']:.1f}us per action)")
    return (f"overhead_batched_pct={out['overhead_batched_pct']:.1f};"
            f"overhead_single_pct={out['overhead_single_pct']:.1f};"
            f"us_traced_batched={out['us_traced_batched']:.1f};"
            f"forwarded={out['forwarded']}")


def bench_chaos():
    """SIGKILL recovery headline: real workers, one killed mid-run; SLOs
    (retire-in-budget, trials re-placed, epochs exact, bit-identical)
    asserted inside. Also bounds no-fault event-emission overhead."""
    from benchmarks import chaos
    out = chaos.run()
    return (f"recovery_s={out['recovery_s']:.3f};"
            f"replaced={out['replaced']};"
            f"obs_overhead_pct={out['overhead']['overhead_pct']:.1f}")


def bench_fig1_tuning_cost():
    from benchmarks import tuning_cost
    rows = tuning_cost.run(max_params=3, epochs=3)
    return (f"growth_1to3={rows[-1]['tuning_time_s']/rows[0]['tuning_time_s']:.0f}x")


def bench_fig8_clustering():
    from benchmarks import clustering
    out = clustering.run(n_per_workload=4)
    return f"purity={out['purity']:.3f}"


def bench_fig2_profiling_stability():
    from benchmarks import profiling_stability
    out = profiling_stability.run(epochs=3, quick=True)
    return f"epoch_profile_separation={out['separation']:.1f}x"


def bench_kernels():
    """Kernel-vs-oracle wall time + correctness on a fixed shape."""
    import jax, jax.numpy as jnp, numpy as np
    from repro.kernels import ops, ref
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 512, 4, 2, 64))
    k = jax.random.normal(ks[1], (1, 512, 4, 64))
    v = jax.random.normal(ks[2], (1, 512, 4, 64))
    out = ops.flash_attention(q, k, v)
    exp = ref.flash_attention_ref(q, k, v)
    err = float(jnp.max(jnp.abs(out - exp)))
    return f"fa_max_err={err:.1e}"


_HILLCLIMB_RECORDS = None       # shared with bench_roofline (one compile)


def bench_hillclimb():
    """§Perf hillclimb smoke: reduced-config variants on a 1x1 mesh, cold
    through the kernel config cache and then warm (cache hits replay the
    stored records without recompiling)."""
    global _HILLCLIMB_RECORDS
    from benchmarks import hillclimb
    from repro.core.groundtruth import KernelConfigDB
    cache = KernelConfigDB()
    t0 = time.monotonic()
    _HILLCLIMB_RECORDS = hillclimb.run(quick=True, cache=cache)
    cold_s = time.monotonic() - t0
    ok = [r for r in _HILLCLIMB_RECORDS if r["status"] == "ok"]
    if len(ok) != len(_HILLCLIMB_RECORDS):
        bad = [r["variant"] for r in _HILLCLIMB_RECORDS
               if r["status"] != "ok"]
        raise RuntimeError(f"hillclimb variants failed to compile: {bad}")
    t0 = time.monotonic()
    warm = hillclimb.run(quick=True, cache=cache)
    warm_s = time.monotonic() - t0
    missed = [r["variant"] for r in warm if not r.get("cached")]
    if missed:
        raise RuntimeError(f"hillclimb warm rerun recompiled: {missed}")
    base = next(r for r in ok if r["variant"] == "baseline")
    best = min(ok, key=lambda r: r["roofline"]["step_time_s"])
    return (f"variants={len(ok)};best={best['variant']};step_ratio="
            f"{best['roofline']['step_time_s']/base['roofline']['step_time_s']:.2f};"
            f"cold_s={cold_s:.1f};warm_s={warm_s:.3f};"
            f"warm_speedup={cold_s/max(warm_s, 1e-9):.0f}x")


def bench_kernel_tune():
    """Kernel autotuning headline: tuned-vs-default wall time per the
    find-db, plus the warm zero-trial re-resolve."""
    from benchmarks import kernel_tune
    out = kernel_tune.run(quick=True)
    b = out["best"]
    trials = sum(r["trials"] for r in out["results"])
    return (f"best={b['workload']};config={json.dumps(b['config'])};"
            f"speedup={b['speedup']:.2f}x;trials={trials};"
            f"warm_trials={out['warm_trials']}")


def bench_roofline():
    """Roofline terms over the hillclimb dry-run records."""
    from benchmarks import roofline
    out = roofline.run(_HILLCLIMB_RECORDS)     # reuses compiles when present
    return f"n={out['n']};dom={out['dominant']};mfu_max={out['mfu_max']:.1e}"


def bench_lint():
    """Full-tree repro.lint run; the suite must stay fast enough to sit in
    the inner dev loop (<10s over src/repro)."""
    import repro.lint as lint
    t0 = time.monotonic()
    project = lint.Project.from_dir(
        os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "src", "repro"),
        lint.default_config())
    findings, suppressed = lint.run_lint(project)
    wall = time.monotonic() - t0
    if wall >= 10.0:
        raise RuntimeError(f"lint took {wall:.1f}s (budget 10s)")
    return (f"modules={len(project.modules)};findings={len(findings)};"
            f"suppressed={suppressed};wall_s={wall:.2f}")


def load_baseline(path):
    # the comparison is advisory: a missing or mangled baseline must not
    # stop the benchmarks from running
    try:
        with open(path) as f:
            return json.load(f)
    except FileNotFoundError:
        print(f"# no baseline at {path}; skipping comparison")
    except (OSError, ValueError) as e:
        print(f"# unreadable baseline at {path} ({e}); skipping comparison")
    return None


def compare_to_baseline(baseline, results, threshold=1.5):
    """Per-bench wall-time delta table vs. a previous summary; returns the
    names regressing past `threshold` (new benchmarks and removed ones are
    reported but never flagged)."""
    base = {r["name"]: r["us_per_call"] for r in baseline.get("benchmarks",
                                                              [])}
    regressions = []
    print(f"# baseline comparison (flag at >{threshold:.2f}x):")
    print(f"# {'benchmark':<24} {'base_ms':>10} {'now_ms':>10} "
          f"{'ratio':>7}  flag")
    for r in results:
        b = base.pop(r["name"], None)
        if b is None or b <= 0:
            print(f"# {r['name']:<24} {'-':>10} "
                  f"{r['us_per_call'] / 1e3:>10.1f} {'-':>7}  new")
            continue
        ratio = r["us_per_call"] / b
        flag = ""
        if ratio > threshold:
            flag = "REGRESSION"
            regressions.append(r["name"])
        print(f"# {r['name']:<24} {b / 1e3:>10.1f} "
              f"{r['us_per_call'] / 1e3:>10.1f} {ratio:>6.2f}x  {flag}")
    for name, b in base.items():
        print(f"# {name:<24} {b / 1e3:>10.1f} {'-':>10} {'-':>7}  removed")
    if regressions:
        print(f"# {len(regressions)} benchmark(s) regressed "
              f">{threshold:.2f}x: {', '.join(regressions)}")
    else:
        print("# no wall-time regressions vs baseline")
    return regressions


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", default=None,
                    help="previous BENCH_results.json to diff wall times "
                         "against (read before this run overwrites it)")
    ap.add_argument("--regress-threshold", type=float, default=1.5,
                    help="flag benchmarks slower than this ratio")
    ap.add_argument("--fail-on-regress", action="store_true",
                    help="exit nonzero when any benchmark is flagged")
    args = ap.parse_args(argv)
    # read the baseline up front: BENCH_OUT may point at the same file
    baseline = load_baseline(args.baseline) if args.baseline else None

    # every bench here already runs its module's quick mode (the scaffold
    # contract: full/slow versions live behind each module's own --full);
    # the summary is written even when a benchmark dies, so a failing CI
    # run still archives the partial timings that led up to the failure
    try:
        _run_all()
    finally:
        write_summary()
    if baseline is not None:
        regressions = compare_to_baseline(baseline, RESULTS,
                                          threshold=args.regress_threshold)
        if regressions and args.fail_on_regress:
            raise SystemExit(1)


def _run_all() -> None:
    _timed("table2", bench_table2)
    _timed("fig9_10_convergence", bench_fig9_10_convergence)
    _timed("fig11_single_tenancy", bench_fig11_single_tenancy)
    _timed("fig12_typeIII", bench_fig12_typeIII)
    _timed("fig12_real_typeIII", bench_fig12_real_typeIII)
    _timed("fig13_14_multi_tenancy", bench_fig13_14_multi_tenancy)
    _timed("async_vs_barrier", bench_async_vs_barrier)
    _timed("elastic", bench_elastic)
    _timed("store_service", bench_store_service)
    _timed("dispatch", bench_dispatch)
    _timed("dispatch_traced", bench_dispatch_traced)
    _timed("chaos", bench_chaos)
    _timed("fig1_tuning_cost", bench_fig1_tuning_cost)
    _timed("fig2_profiling_stability", bench_fig2_profiling_stability)
    _timed("fig8_clustering", bench_fig8_clustering)
    # kernels initializes the jax CPU backend before the dryrun import below
    # can request 512 host devices, keeping the compile cells single-device
    _timed("kernels", bench_kernels)
    _timed("kernel_tune", bench_kernel_tune)
    _timed("hillclimb", bench_hillclimb)
    _timed("roofline", bench_roofline)
    _timed("lint", bench_lint)


if __name__ == "__main__":
    main()
