"""Elastic vs static allocation under bursty arrivals (§7.4 elasticity).

The §7.4 docstring always promised "jobs may shrink to fewer chips when
the queue is long"; this bench measures what that buys. A burst of HPT
jobs lands on a small shared cluster:

* **static** — the fixed full-speed nodes; late arrivals queue behind the
  burst.
* **elastic** — ``ElasticPolicy``: under queue pressure full nodes split
  into fractional ones (each job runs on fewer chips — slower epochs, but
  sublinearly so, per the Fig 3b perf model), so more of the burst runs at
  once; jobs caught on a splitting node re-shard at their next epoch
  boundary (restore + reconfig charge) and the split merges back once the
  queue drains.

Headline: mean job response time (queue + service), elastic vs static.
Elastic wins when the queueing a split removes outweighs the slower
epochs plus the reshard charges it introduces — which is exactly the
bursty-arrival regime.
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from benchmarks import common
from repro.cluster.sim import (ClusterConfig, ClusterSim, ElasticPolicy,
                               make_arrivals)
from repro.core import GroundTruth


def run(n_jobs=10, n_nodes=2, mean_arrival=30.0, seed=0, split_speed=0.65,
        n_trials=2, max_epochs=4):
    """One burst, three policies: static, elastic, and elastic re-run (the
    determinism check). Returns mean/p95 response per policy."""
    space = common.paper_space(small=True)
    jobs = make_arrivals(["lenet-mnist", "cnn-news20"], n_jobs=n_jobs,
                         mean_interarrival_s=mean_arrival, space=space,
                         max_epochs=max_epochs, seed=seed)

    def simulate(policy):
        # fresh store per policy run: cross-job learning stays inside one
        # simulated cluster, never leaks across the compared variants
        factory = common.sim_runners(gt=GroundTruth(), seed=seed)["PipeTune"]
        sim = ClusterSim(ClusterConfig(n_nodes=n_nodes, seed=seed),
                         factory, elastic=policy)
        res = sim.run(jobs, scheduler="random", n_trials=n_trials)
        resp = [o.response_s for o in res]
        return {
            "mean_response_s": float(np.mean(resp)),
            "p95_response_s": float(np.percentile(resp, 95)),
            "makespan_s": float(max(o.finish for o in res)),
            "reshards": int(sum(o.n_preemptions for o in res)),
            "accuracies": [o.best_accuracy for o in res],
        }

    static = simulate(None)
    policy = ElasticPolicy(split_queue=2, split_speed=split_speed)
    elastic = simulate(policy)
    rerun = simulate(ElasticPolicy(split_queue=2, split_speed=split_speed))
    assert elastic == rerun, "elastic sim is not deterministic"
    # elasticity reconfigures *where and when* epochs run, never what they
    # compute: accuracies must match the static cluster exactly
    assert elastic["accuracies"] == static["accuracies"]
    return {
        "static": static, "elastic": elastic,
        "splits": policy.n_splits, "merges": policy.n_merges,
        "response_reduction": 1.0 - (elastic["mean_response_s"]
                                     / static["mean_response_s"]),
    }


def main(quick=True):
    out = run(n_jobs=10 if quick else 24)
    s, e = out["static"], out["elastic"]
    print(f"static : mean={s['mean_response_s']:8.1f}s "
          f"p95={s['p95_response_s']:8.1f}s makespan={s['makespan_s']:8.1f}s")
    print(f"elastic: mean={e['mean_response_s']:8.1f}s "
          f"p95={e['p95_response_s']:8.1f}s makespan={e['makespan_s']:8.1f}s "
          f"({out['splits']} splits, {out['merges']} merges, "
          f"{e['reshards']} reshards)")
    print(f"mean response reduction: {100 * out['response_reduction']:.1f}%")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default=None)
    a = ap.parse_args()
    result = main(quick=not a.full)
    if a.out:
        json.dump(result, open(a.out, "w"), indent=1)
