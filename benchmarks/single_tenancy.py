"""Paper Fig 11/12: single-tenancy accuracy / training / tuning / energy per
workload for Tune V1, Tune V2, PipeTune.

Type-I/II (Fig 11) run on the 4-node cluster model; Type-III (Fig 12) on a
single node with short epochs (the adversarial case for PipeTune's
epoch-granular profiling).
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from benchmarks import common
from repro.core import GroundTruth
from repro.core.job import HPTJob

TYPE_I_II = ["lenet-mnist", "lenet-fashion", "cnn-news20", "lstm-news20"]
TYPE_III = ["jacobi-rodinia", "spkmeans-rodinia", "bfs-rodinia"]


def run(workloads, seed=0, shared_gt=True):
    space = common.paper_space(small=False)
    gt = GroundTruth()
    out = {}
    for wl in workloads:
        job = HPTJob(workload=wl, space=space, max_epochs=9, seed=seed)
        row = {}
        for name in common.TUNERS:
            res = common.experiment(
                job, name, seed=seed,
                gt=gt if shared_gt else GroundTruth()).run()
            row[name] = dict(
                accuracy=res.best_accuracy,
                training_time_s=res.best_train_time,
                tuning_time_s=res.tuning_time_s,
                energy_j=res.energy_j)
        out[wl] = row
    return out


def _summary(out, label):
    print(f"--- {label} ---")
    print(f"{'workload':18s} {'system':9s} {'acc':>6s} {'train[s]':>9s} "
          f"{'tune[s]':>9s} {'energy[kJ]':>11s}")
    for wl, row in out.items():
        for name, r in row.items():
            print(f"{wl:18s} {name:9s} {r['accuracy']:6.3f} "
                  f"{r['training_time_s']:9.1f} {r['tuning_time_s']:9.1f} "
                  f"{r['energy_j']/1e3:11.1f}")
    # headline deltas (paper: >=18% tuning reduction, <=29% energy reduction)
    red_t, red_e = [], []
    for row in out.values():
        red_t.append(1 - row["PipeTune"]["tuning_time_s"]
                     / row["TuneV1"]["tuning_time_s"])
        red_e.append(1 - row["PipeTune"]["energy_j"]
                     / row["TuneV1"]["energy_j"])
    print(f"PipeTune vs V1: tuning-time reduction mean "
          f"{100*np.mean(red_t):.1f}% (max {100*np.max(red_t):.1f}%), "
          f"energy reduction mean {100*np.mean(red_e):.1f}% "
          f"(max {100*np.max(red_e):.1f}%)")
    return {"tuning_reduction_max": float(np.max(red_t)),
            "energy_reduction_max": float(np.max(red_e))}


def main():
    out12 = run(TYPE_I_II)
    s1 = _summary(out12, "Fig 11: Type-I/II")
    out3 = run(TYPE_III)
    s3 = _summary(out3, "Fig 12: Type-III (short epochs)")
    return {"fig11": out12, "fig12": out3, "headline": {**s1, **s3}}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None)
    a = ap.parse_args()
    out = main()
    if a.out:
        json.dump(out, open(a.out, "w"), indent=1)
