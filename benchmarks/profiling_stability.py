"""Paper Fig 2: per-epoch hardware profiles repeat across epochs.

Trains a real workload for several epochs and measures (a) within-trial
profile distances across epochs — the paper's 'events repeat throughout the
epochs with the same occurrence' — versus (b) across-workload distances,
which must be far larger (this gap is why epoch-0 profiling predicts the
remaining epochs and why k-means separates workloads).
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.core.backends import RealBackend, SYS_DEFAULT


def run(epochs=5, quick=True):
    backend = RealBackend(n_train=512 if quick else 2048,
                          n_eval=128, steps_per_epoch=6)
    vecs = {}
    for wl in ("lenet-mnist", "cnn-news20"):
        ts = backend.init_trial(wl, {"batch_size": 64,
                                     "learning_rate": 0.01}, seed=0)
        rows = []
        for _ in range(epochs):
            ts, res = backend.run_epoch(ts, dict(SYS_DEFAULT))
            rows.append(res.profile.vector())
        vecs[wl] = np.stack(rows)

    def mean_dist(A, B):
        return float(np.mean([np.linalg.norm(a - b)
                              for a in A for b in B if a is not b]))

    within = {wl: mean_dist(v[1:], v[1:]) for wl, v in vecs.items()}
    across = mean_dist(vecs["lenet-mnist"][1:], vecs["cnn-news20"][1:])
    return {"within": within, "across": across,
            "separation": across / max(max(within.values()), 1e-9)}


def main():
    out = run()
    print(f"within-trial epoch-to-epoch profile distance: "
          f"{ {k: round(v, 3) for k, v in out['within'].items()} }")
    print(f"across-workload distance: {out['across']:.3f}")
    print(f"separation ratio: {out['separation']:.1f}x "
          f"(paper Fig 2: epochs repeat; Fig 8: workloads separate)")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None)
    a = ap.parse_args()
    out = main()
    if a.out:
        json.dump(out, open(a.out, "w"), indent=1)
