"""Dispatch-overhead microbenchmark: µs per trial action over the worker
wire — JSON vs negotiated binary codec, one request per trial vs batched
``run_many`` — the fixed cost every real trial pays before any training
happens.

The server is a canned-response trial service behind the real
``JsonRPCServer`` (selector loop + handler pool) and the real
``SocketTransport`` framing, so the numbers isolate codec + framing +
server turnaround from backend simulation time. Payloads mimic the real
protocol's shapes (hparams dict out, record-with-epochs back).

Run directly for the full version:  PYTHONPATH=src python -m benchmarks.dispatch
"""
from __future__ import annotations

import time

from repro.service import JsonRPCServer, SocketTransport
from repro.service.codec import best_binary_codec


def _canned_record(trial_id: str, epochs: int = 5) -> dict:
    return {
        "trial_id": trial_id,
        "workload": "lenet-mnist",
        "hparams": {"batch_size": 256, "learning_rate": 0.0125},
        "epochs": [{"epoch": e, "accuracy": 0.62 + 0.04 * e,
                    "loss": 1.9 / (e + 1), "duration_s": 11.372 + 0.01 * e}
                   for e in range(epochs)],
        "sys_history": [[e, {"microbatches": 4, "remat": "block",
                             "precision": "bf16"}] for e in range(epochs)],
        "gt_hit": False,
        "probe_epochs": 2,
    }


class _CannedTrialService:
    """The worker protocol's request/response shapes with zero backend
    work: what remains is exactly the dispatch overhead under test."""

    def handle(self, req: dict) -> dict:
        op = req.get("op")
        if op == "run":
            return {"ok": True,
                    "record": _canned_record(str(req.get("trial_id")))}
        if op == "run_many":
            return {"ok": True, "results": [
                {"ok": True, "record": _canned_record(str(t.get("trial_id")))}
                for t in req.get("trials", [])]}
        return {"ok": False, "error": f"unknown op {op!r}"}


def _run_request(trial_id: str) -> dict:
    return {"op": "run", "workload": "lenet-mnist", "trial_id": trial_id,
            "hparams": {"batch_size": 256, "learning_rate": 0.0125},
            "epochs": 5}


def _measure_single(addr, wire: str, n: int) -> float:
    """µs per trial action, one round-trip per trial."""
    t = SocketTransport(*addr, wire=wire)
    t.request(_run_request("warmup"))            # connection + codec settled
    t0 = time.perf_counter()
    for i in range(n):
        resp = t.request(_run_request(f"t{i}"))
        assert resp.get("ok"), resp
    dt = time.perf_counter() - t0
    t.close()
    return dt * 1e6 / n


def _measure_batched(addr, wire: str, n: int, batch: int) -> float:
    """µs per trial action, one ``run_many`` round-trip per wave."""
    t = SocketTransport(*addr, wire=wire)
    t.request(_run_request("warmup"))
    waves, count = [], 0
    while count < n:
        size = min(batch, n - count)
        waves.append([{"trial_id": f"b{count + j}",
                       "hparams": {"batch_size": 256,
                                   "learning_rate": 0.0125},
                       "epochs": 5} for j in range(size)])
        count += size
    t0 = time.perf_counter()
    for trials in waves:
        resp = t.request({"op": "run_many", "workload": "lenet-mnist",
                          "trials": trials})
        assert resp.get("ok") and len(resp["results"]) == len(trials), resp
    dt = time.perf_counter() - t0
    t.close()
    return dt * 1e6 / n


def _measure_traced_single(addr, wire: str, n: int, bus) -> float:
    """µs per trial action with tracing on: ``_trace`` metadata stamped on
    every request, an ``RpcCompleted`` receipt emitted per action onto an
    enabled bus whose ``ForwardingSink`` ships to a live collector — the
    exact per-request work the traced driver path adds."""
    from repro.obs.events import RpcCompleted
    t = SocketTransport(*addr, wire=wire)
    t.trace = "bench0123456789ab"
    t.request(_run_request("warmup"))
    t0 = time.perf_counter()
    for i in range(n):
        r0 = time.perf_counter()
        resp = t.request(_run_request(f"t{i}"))
        dt = time.perf_counter() - r0
        assert resp.get("ok"), resp
        bus.emit(RpcCompleted(op="run", peer=f"tcp://{addr[0]}:{addr[1]}",
                              duration_s=dt, overhead_s=dt))
    total = time.perf_counter() - t0
    t.close()
    return total * 1e6 / n


def _measure_traced_batched(addr, wire: str, n: int, batch: int,
                            bus) -> float:
    """Traced ``run_many``: one receipt per wave (the production path)."""
    from repro.obs.events import RpcCompleted
    t = SocketTransport(*addr, wire=wire)
    t.trace = "bench0123456789ab"
    t.request(_run_request("warmup"))
    waves, count = [], 0
    while count < n:
        size = min(batch, n - count)
        waves.append([{"trial_id": f"b{count + j}",
                       "hparams": {"batch_size": 256,
                                   "learning_rate": 0.0125},
                       "epochs": 5} for j in range(size)])
        count += size
    t0 = time.perf_counter()
    for trials in waves:
        r0 = time.perf_counter()
        resp = t.request({"op": "run_many", "workload": "lenet-mnist",
                          "trials": trials})
        dt = time.perf_counter() - r0
        assert resp.get("ok") and len(resp["results"]) == len(trials), resp
        bus.emit(RpcCompleted(op="run_many", peer="bench",
                              duration_s=dt, overhead_s=dt,
                              n=len(trials)))
    total = time.perf_counter() - t0
    t.close()
    return total * 1e6 / n


def run_traced(n_actions: int = 2000, batch: int = 32,
               repeats: int = 3) -> dict:
    """Tracing-overhead headline: the dispatch bench with tracing off vs
    on (trace metadata + per-action receipts + forwarding to a live
    collector). Best-of-``repeats`` per variant, interleaved, so scheduler
    noise hits both sides alike. The acceptance bar is < 5% overhead."""
    from repro.obs.events import EventBus
    from repro.obs.forward import start_collector, ForwardingSink

    server = JsonRPCServer(("127.0.0.1", 0), _CannedTrialService().handle)
    import threading
    threading.Thread(target=server.serve_forever, daemon=True).start()
    addr = ("127.0.0.1", server.server_address[1])
    wire = best_binary_codec().name

    sink_bus = EventBus()                   # collector's home bus
    collector = start_collector(sink_bus)
    bus = EventBus()                        # the traced driver's bus
    bus.trace_id, bus.proc = "bench0123456789ab", "driver"
    fwd = ForwardingSink(collector.address, proc="driver")
    bus.add_sink(fwd)

    plain_s, traced_s = [], []
    plain_b, traced_b = [], []
    try:
        for _ in range(max(1, repeats)):
            plain_s.append(_measure_single(addr, wire, n_actions))
            traced_s.append(_measure_traced_single(addr, wire, n_actions,
                                                   bus))
            fwd.flush(timeout=1.0)      # don't bleed into the next timing
            plain_b.append(_measure_batched(addr, wire, n_actions, batch))
            traced_b.append(_measure_traced_batched(addr, wire, n_actions,
                                                    batch, bus))
            fwd.flush(timeout=1.0)
    finally:
        fwd.close()
        collector.close(drain_s=0.1)
        server.shutdown()
    out = {
        "n_actions": n_actions, "batch": batch, "wire": wire,
        "us_plain_single": min(plain_s),
        "us_traced_single": min(traced_s),
        "us_plain_batched": min(plain_b),
        "us_traced_batched": min(traced_b),
        "forwarded": sink_bus.seq,
    }
    out["overhead_single_pct"] = 100.0 * (
        out["us_traced_single"] / out["us_plain_single"] - 1.0)
    out["overhead_batched_pct"] = 100.0 * (
        out["us_traced_batched"] / out["us_plain_batched"] - 1.0)
    return out


def run(n_actions: int = 2000, batch: int = 32, quick: bool = True) -> dict:
    server = JsonRPCServer(("127.0.0.1", 0), _CannedTrialService().handle)
    import threading
    threading.Thread(target=server.serve_forever, daemon=True).start()
    addr = ("127.0.0.1", server.server_address[1])
    binary = best_binary_codec().name
    try:
        out = {
            "n_actions": n_actions, "batch": batch,
            "binary_codec": binary,
            "us_json_single": _measure_single(addr, "json", n_actions),
            "us_binary_single": _measure_single(addr, binary, n_actions),
            "us_json_batched": _measure_batched(addr, "json", n_actions,
                                                batch),
            "us_binary_batched": _measure_batched(addr, binary, n_actions,
                                                  batch),
        }
    finally:
        server.shutdown()
    out["batch_speedup"] = out["us_json_single"] / out["us_binary_batched"]
    return out


if __name__ == "__main__":
    res = run(n_actions=20000, batch=64, quick=False)
    res.update(run_traced(n_actions=20000, batch=64))
    for k, v in res.items():
        print(f"{k}: {v:.2f}" if isinstance(v, float) else f"{k}: {v}")
