"""Dispatch-overhead microbenchmark: µs per trial action over the worker
wire — JSON vs negotiated binary codec, one request per trial vs batched
``run_many`` — the fixed cost every real trial pays before any training
happens.

The server is a canned-response trial service behind the real
``JsonRPCServer`` (selector loop + handler pool) and the real
``SocketTransport`` framing, so the numbers isolate codec + framing +
server turnaround from backend simulation time. Payloads mimic the real
protocol's shapes (hparams dict out, record-with-epochs back).

Run directly for the full version:  PYTHONPATH=src python -m benchmarks.dispatch
"""
from __future__ import annotations

import time

from repro.service import JsonRPCServer, SocketTransport
from repro.service.codec import best_binary_codec


def _canned_record(trial_id: str, epochs: int = 5) -> dict:
    return {
        "trial_id": trial_id,
        "workload": "lenet-mnist",
        "hparams": {"batch_size": 256, "learning_rate": 0.0125},
        "epochs": [{"epoch": e, "accuracy": 0.62 + 0.04 * e,
                    "loss": 1.9 / (e + 1), "duration_s": 11.372 + 0.01 * e}
                   for e in range(epochs)],
        "sys_history": [[e, {"microbatches": 4, "remat": "block",
                             "precision": "bf16"}] for e in range(epochs)],
        "gt_hit": False,
        "probe_epochs": 2,
    }


class _CannedTrialService:
    """The worker protocol's request/response shapes with zero backend
    work: what remains is exactly the dispatch overhead under test."""

    def handle(self, req: dict) -> dict:
        op = req.get("op")
        if op == "run":
            return {"ok": True,
                    "record": _canned_record(str(req.get("trial_id")))}
        if op == "run_many":
            return {"ok": True, "results": [
                {"ok": True, "record": _canned_record(str(t.get("trial_id")))}
                for t in req.get("trials", [])]}
        return {"ok": False, "error": f"unknown op {op!r}"}


def _run_request(trial_id: str) -> dict:
    return {"op": "run", "workload": "lenet-mnist", "trial_id": trial_id,
            "hparams": {"batch_size": 256, "learning_rate": 0.0125},
            "epochs": 5}


def _measure_single(addr, wire: str, n: int) -> float:
    """µs per trial action, one round-trip per trial."""
    t = SocketTransport(*addr, wire=wire)
    t.request(_run_request("warmup"))            # connection + codec settled
    t0 = time.perf_counter()
    for i in range(n):
        resp = t.request(_run_request(f"t{i}"))
        assert resp.get("ok"), resp
    dt = time.perf_counter() - t0
    t.close()
    return dt * 1e6 / n


def _measure_batched(addr, wire: str, n: int, batch: int) -> float:
    """µs per trial action, one ``run_many`` round-trip per wave."""
    t = SocketTransport(*addr, wire=wire)
    t.request(_run_request("warmup"))
    waves, count = [], 0
    while count < n:
        size = min(batch, n - count)
        waves.append([{"trial_id": f"b{count + j}",
                       "hparams": {"batch_size": 256,
                                   "learning_rate": 0.0125},
                       "epochs": 5} for j in range(size)])
        count += size
    t0 = time.perf_counter()
    for trials in waves:
        resp = t.request({"op": "run_many", "workload": "lenet-mnist",
                          "trials": trials})
        assert resp.get("ok") and len(resp["results"]) == len(trials), resp
    dt = time.perf_counter() - t0
    t.close()
    return dt * 1e6 / n


def run(n_actions: int = 2000, batch: int = 32, quick: bool = True) -> dict:
    server = JsonRPCServer(("127.0.0.1", 0), _CannedTrialService().handle)
    import threading
    threading.Thread(target=server.serve_forever, daemon=True).start()
    addr = ("127.0.0.1", server.server_address[1])
    binary = best_binary_codec().name
    try:
        out = {
            "n_actions": n_actions, "batch": batch,
            "binary_codec": binary,
            "us_json_single": _measure_single(addr, "json", n_actions),
            "us_binary_single": _measure_single(addr, binary, n_actions),
            "us_json_batched": _measure_batched(addr, "json", n_actions,
                                                batch),
            "us_binary_batched": _measure_batched(addr, binary, n_actions,
                                                  batch),
        }
    finally:
        server.shutdown()
    out["batch_speedup"] = out["us_json_single"] / out["us_binary_batched"]
    return out


if __name__ == "__main__":
    res = run(n_actions=20000, batch=64, quick=False)
    for k, v in res.items():
        print(f"{k}: {v:.2f}" if isinstance(v, float) else f"{k}: {v}")
