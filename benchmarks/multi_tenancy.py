"""Paper Fig 13/14: multi-tenant average response time.

Fig 13: Type-I and Type-II jobs on the shared 4-node cluster, separately and
mixed. Fig 14: Type-III on a single node. 20% unseen jobs (paper §7.4).
Also reports the fault-tolerance variants (failures + stragglers) — beyond
the paper, required for the 1000+ node story. Jobs execute on the
discrete-event engine (``mode="event"``), so stragglers and failures hit
epochs as they run; ``async_vs_barrier`` measures what that buys a truly
asynchronous scheduler (AsyncASHA) over rung-synchronized HyperBand.
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from benchmarks import common
from repro.cluster.executor import ClusterTrialExecutor
from repro.cluster.sim import (ClusterConfig, ClusterSim, SIM_SYS_DEFAULT,
                               make_arrivals)
from repro.core import GroundTruth


def scenario(workloads, n_jobs, n_nodes, seed=0, mean_arrival=400.0,
             cluster_kw=None, n_trials=5, mode="event"):
    space = common.paper_space(small=False)
    jobs = make_arrivals(workloads, n_jobs=n_jobs,
                         mean_interarrival_s=mean_arrival, space=space,
                         max_epochs=9, seed=seed, unseen_frac=0.2)
    factories = common.sim_runners(gt=GroundTruth(), seed=seed)
    out = {}
    for name, f in factories.items():
        sim = ClusterSim(ClusterConfig(n_nodes=n_nodes, seed=seed,
                                       **(cluster_kw or {})), f, mode=mode)
        res = sim.run(jobs, scheduler="random", n_trials=n_trials)
        out[name] = {
            "mean_response_s": float(np.mean([o.response_s for o in res])),
            "mean_accuracy": float(np.mean([o.best_accuracy for o in res])),
            "by_type": {t: float(np.mean([o.response_s for o in res
                                          if o.jtype == t]) or 0)
                        for t in {o.jtype for o in res}},
            "failures": int(sum(o.n_failures for o in res)),
            "stragglers": int(sum(o.n_stragglers for o in res)),
        }
    return out


def async_vs_barrier(seed=0, straggler_prob=0.3, n_nodes=4, max_epochs=9):
    """One HPT job's trials dispatched onto simulated nodes: simulated time
    until the first final-rung (R-epoch) trial completes, AsyncASHA vs
    barrier-synchronized HyperBand. The asynchrony win: promotions that
    straggling wave-mates cannot block."""
    from repro.api import Experiment
    from repro.core.job import HPTJob
    job = HPTJob(workload="lenet-mnist", space=common.paper_space(),
                 max_epochs=max_epochs, seed=seed)
    out = {}
    for sched, kw in (("asha-async", {"n_trials": 9}), ("hyperband", {})):
        ex = ClusterTrialExecutor(
            cluster=ClusterConfig(n_nodes=n_nodes,
                                  straggler_prob=straggler_prob, seed=seed),
            default_sys=SIM_SYS_DEFAULT)
        res = (Experiment(job).with_tuner("v1").with_backend("sim")
               .with_scheduler(sched, **kw).run(executor=ex))
        final = [h.finish_s for h in ex.history if h.epochs == max_epochs]
        out[sched] = {"final_rung_s": min(final) if final else float("nan"),
                      "makespan_s": res.sim_time_s,
                      "best_accuracy": res.best_accuracy,
                      "stragglers": sum(h.n_stragglers for h in ex.history)}
    return out


def main(quick=True):
    n = 8 if quick else 24
    results = {}
    results["fig13_typeI"] = scenario(["lenet-mnist", "lenet-fashion"], n, 4)
    results["fig13_typeII"] = scenario(["cnn-news20", "lstm-news20"], n, 4)
    results["fig13_mixed"] = scenario(
        ["lenet-mnist", "cnn-news20", "lenet-fashion", "lstm-news20"], n, 4)
    results["fig14_typeIII"] = scenario(
        ["jacobi-rodinia", "spkmeans-rodinia", "bfs-rodinia"], n, 1,
        mean_arrival=120.0)
    results["faulty"] = scenario(
        ["lenet-mnist", "cnn-news20"], n, 4,
        cluster_kw=dict(mtbf_s=20000.0, straggler_prob=0.05))

    for scen, rows in results.items():
        v1 = rows["TuneV1"]["mean_response_s"]
        pt = rows["PipeTune"]["mean_response_s"]
        print(f"{scen:16s} V1={v1:9.1f}s V2="
              f"{rows['TuneV2']['mean_response_s']:9.1f}s "
              f"PipeTune={pt:9.1f}s  reduction_vs_V1={100*(1-pt/v1):5.1f}% "
              f"acc V1/PT={rows['TuneV1']['mean_accuracy']:.3f}/"
              f"{rows['PipeTune']['mean_accuracy']:.3f}")

    ab = async_vs_barrier()
    results["async_vs_barrier"] = ab
    a, h = ab["asha-async"], ab["hyperband"]
    print(f"{'async_vs_barrier':16s} AsyncASHA final rung at "
          f"{a['final_rung_s']:.0f}s (makespan {a['makespan_s']:.0f}s) vs "
          f"HyperBand {h['final_rung_s']:.0f}s "
          f"(makespan {h['makespan_s']:.0f}s)")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default=None)
    a = ap.parse_args()
    out = main(quick=not a.full)
    if a.out:
        json.dump(out, open(a.out, "w"), indent=1)
