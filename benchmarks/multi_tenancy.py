"""Paper Fig 13/14: multi-tenant average response time.

Fig 13: Type-I and Type-II jobs on the shared 4-node cluster, separately and
mixed. Fig 14: Type-III on a single node. 20% unseen jobs (paper §7.4).
Also reports the fault-tolerance variants (failures + stragglers) — beyond
the paper, required for the 1000+ node story.
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from benchmarks import common
from repro.cluster.sim import ClusterConfig, ClusterSim, make_arrivals
from repro.core import GroundTruth


def scenario(workloads, n_jobs, n_nodes, seed=0, mean_arrival=400.0,
             cluster_kw=None, n_trials=5):
    space = common.paper_space(small=False)
    jobs = make_arrivals(workloads, n_jobs=n_jobs,
                         mean_interarrival_s=mean_arrival, space=space,
                         max_epochs=9, seed=seed, unseen_frac=0.2)
    factories = common.sim_runners(gt=GroundTruth(), seed=seed)
    out = {}
    for name, f in factories.items():
        sim = ClusterSim(ClusterConfig(n_nodes=n_nodes, seed=seed,
                                       **(cluster_kw or {})), f)
        res = sim.run(jobs, scheduler="random", n_trials=n_trials)
        out[name] = {
            "mean_response_s": float(np.mean([o.response_s for o in res])),
            "mean_accuracy": float(np.mean([o.best_accuracy for o in res])),
            "by_type": {t: float(np.mean([o.response_s for o in res
                                          if o.jtype == t]) or 0)
                        for t in {o.jtype for o in res}},
            "failures": int(sum(o.n_failures for o in res)),
            "stragglers": int(sum(o.n_stragglers for o in res)),
        }
    return out


def main(quick=True):
    n = 8 if quick else 24
    results = {}
    results["fig13_typeI"] = scenario(["lenet-mnist", "lenet-fashion"], n, 4)
    results["fig13_typeII"] = scenario(["cnn-news20", "lstm-news20"], n, 4)
    results["fig13_mixed"] = scenario(
        ["lenet-mnist", "cnn-news20", "lenet-fashion", "lstm-news20"], n, 4)
    results["fig14_typeIII"] = scenario(
        ["jacobi-rodinia", "spkmeans-rodinia", "bfs-rodinia"], n, 1,
        mean_arrival=120.0)
    results["faulty"] = scenario(
        ["lenet-mnist", "cnn-news20"], n, 4,
        cluster_kw=dict(mtbf_s=20000.0, straggler_prob=0.05))

    for scen, rows in results.items():
        v1 = rows["TuneV1"]["mean_response_s"]
        pt = rows["PipeTune"]["mean_response_s"]
        print(f"{scen:16s} V1={v1:9.1f}s V2="
              f"{rows['TuneV2']['mean_response_s']:9.1f}s "
              f"PipeTune={pt:9.1f}s  reduction_vs_V1={100*(1-pt/v1):5.1f}% "
              f"acc V1/PT={rows['TuneV1']['mean_accuracy']:.3f}/"
              f"{rows['PipeTune']['mean_accuracy']:.3f}")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default=None)
    a = ap.parse_args()
    out = main(quick=not a.full)
    if a.out:
        json.dump(out, open(a.out, "w"), indent=1)
