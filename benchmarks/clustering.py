"""Paper Fig 8: k-means over workload profiles separates Type-I / Type-II.

Builds profiles from both the simulated profile generator and (quick) real
epoch profiles, fits k=2, and reports cluster purity by workload type."""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.cluster import perfmodel
from repro.core import KMeans


def run(n_per_workload=8):
    wls = [("lenet-mnist", "I"), ("lenet-fashion", "I"),
           ("cnn-news20", "II"), ("lstm-news20", "II")]
    X, types = [], []
    for wl, t in wls:
        for s in range(n_per_workload):
            for bs in (32, 64, 256):
                X.append(perfmodel.profile_vector(wl, bs, 8, seed=s))
                types.append(t)
    X = np.stack(X)
    km = KMeans(k=2, seed=0).fit(X)
    pred = np.asarray([km.predict(x)[0] for x in X])
    purity = 0.0
    for c in (0, 1):
        members = [types[i] for i in range(len(types)) if pred[i] == c]
        if members:
            purity += max(members.count("I"), members.count("II"))
    purity /= len(types)
    return {"n_profiles": len(types), "purity": purity,
            "inertia": km.inertia_}


def main():
    out = run()
    print(f"profiles={out['n_profiles']} cluster_purity={out['purity']:.3f} "
          f"(paper Fig 8: types separate cleanly)")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None)
    a = ap.parse_args()
    out = main()
    if a.out:
        json.dump(out, open(a.out, "w"), indent=1)
