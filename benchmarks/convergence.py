"""Paper Fig 9/10: accuracy + per-trial training-time convergence over the
tuning timeline (CNN on News20-like), PipeTune vs Tune V1/V2 (SimBackend for
the full timeline; --real uses RealBackend)."""
from __future__ import annotations

import argparse
import json

import numpy as np

from benchmarks import common
from repro.core.job import HPTJob


def trace(runner, job, scheduler="hyperband", **kw):
    """Returns [(cum_tuning_time, best_acc_so_far, trial_train_time)]."""
    res = runner.run_job(job, scheduler=scheduler, **kw)
    events = []
    t, best = 0.0, 0.0
    recs = list(res.records.values())
    for rec in recs:
        t += rec.train_time
        best = max(best, rec.accuracy)
        events.append((t, best, rec.train_time))
    return events, res


def run(quick=True, workload="cnn-news20", seed=0):
    space = common.paper_space(small=False)
    job = HPTJob(workload=workload, space=space, max_epochs=9, seed=seed)
    out = {}
    for name in ("TuneV1", "TuneV2", "PipeTune"):
        runner = common.experiment(job, name, seed=seed).build_runner()
        events, res = trace(runner, job)
        out[name] = {"events": events,
                     "final_acc": res.best_accuracy,
                     "tuning_time": res.tuning_time_s}
    return out


def main(quick=True):
    out = run(quick)
    t_target = 0.6 * max(v["final_acc"] for v in out.values())
    print(f"{'System':9s} {'final_acc':>9s} {'tuning[s]':>10s} "
          f"{'t@60%acc[s]':>12s}")
    for name, v in out.items():
        t60 = next((t for t, acc, _ in v["events"] if acc >= t_target),
                   float("nan"))
        print(f"{name:9s} {v['final_acc']:9.3f} {v['tuning_time']:10.1f} "
              f"{t60:12.1f}")
    v1, pt = out["TuneV1"]["tuning_time"], out["PipeTune"]["tuning_time"]
    print(f"PipeTune tuning speedup vs V1: {v1 / pt:.2f}x")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None)
    a = ap.parse_args()
    out = main()
    if a.out:
        json.dump(out, open(a.out, "w"), indent=1)
